//! Repo-invariant gate and campaign driver: `cargo xtask {lint,
//! analyze, graph, swarm}`.
//!
//! Dependency-free, in-tree static tooling (the offline build image
//! cannot fetch crates), plus the nemesis-swarm CLI. Four subcommands:
//!
//! * `lint` (default) — seven line-oriented rules running on the
//!   lexer's [`lexer::code_view`] (comments and string/char literals
//!   blanked, so `unsafe` in a doc comment or `//` inside a string
//!   can no longer produce false verdicts):
//!   1. **safety-comments** — every `unsafe` token in `rust/src/net/`
//!      must carry a `// SAFETY:` comment on the same line or on the
//!      comment/attribute block immediately above it.
//!   2. **sync-facade** — modules migrated onto the `crate::sync`
//!      facade must not name `std::sync::` / `std::thread` directly
//!      outside `#[cfg(test)]`, or the loom model (`--cfg loom`)
//!      silently loses coverage. `net/epoll.rs` / `net/uring.rs` are
//!      exempt by design: their atomics live in kernel-shared mmap'd
//!      memory and must stay real.
//!   3. **codec-tags** — tag bytes in the decode matches must be
//!      unique per function; a duplicate silently shadows a variant.
//!   4. **payload-alloc** — protocol hot-path code must not
//!      materialise payload bytes or allocate per-event vectors;
//!      audited cold sites carry `// alloc-ok: <reason>`.
//!   5. **unordered-iter** — `HashMap`/`FxHashMap` identifiers in the
//!      protocol core must not be iterated (hash order is
//!      nondeterministic and tends to reach the wire); audited sites
//!      carry `// unordered-ok: <reason>`.
//!   6. **exporter-coverage** — every `pub <field>: AtomicU64` counter
//!      in `CoordStats` / `NetStats` / `StorageStats` must be read in
//!      `rust/src/obs/export.rs`, so a stats field added without a
//!      `/metrics` export fails the gate instead of silently missing
//!      from dashboards.
//!   7. **nemesis-reach** — the simulator's fault-injection knobs
//!      (`net_partition`, `clock_skew`, `disk_fault_at`, `arm_fault`,
//!      …) must be unreachable from non-test, non-sim code paths;
//!      audited sites carry `// nemesis-ok: <reason>`. A partition
//!      knob reachable from production would be a self-inflicted
//!      outage primitive.
//! * `analyze` — the protocol-aware analyses in [`analyze`]:
//!   journal-before-ack dataflow, `Wire` exhaustiveness, lock-order
//!   deadlock freedom, and blocking-call-in-event-loop reachability.
//! * `graph` — emit the generated message-flow and lock-order DOT
//!   figures (see [`graph`]).
//! * `swarm` — the deterministic fault-injection campaign
//!   ([`swarm`]): run seeded [`wbam::sim::nemesis::NemesisSchedule`]s
//!   under the strict invariant suite, dump failing schedules as JSON
//!   with their flight-recorder tails, and delta-debug reproducers
//!   (`--repro file.json`).
//!
//! Exit status 1 with one line per violation; 0 on a clean tree. See
//! ARCHITECTURE.md §Correctness tooling for the rule ↔ invariant table.

mod analyze;
mod graph;
mod lexer;
mod parser;
mod swarm;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Debug, PartialEq)]
struct Violation {
    file: String,
    line: usize, // 1-based
    rule: &'static str,
    msg: String,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        None | Some("lint") => lint(),
        Some("analyze") => analyze_cmd(),
        Some("graph") => graph::run(&repo_root()),
        Some("swarm") => swarm::run(&args[1..]),
        Some(other) => {
            eprintln!("unknown xtask command {other:?} (commands: lint, analyze, graph, swarm)");
            ExitCode::FAILURE
        }
    }
}

/// xtask lives at `<repo>/rust/xtask`.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().parent().unwrap().to_path_buf()
}

fn report(label: &str, checked: &str, violations: &[Violation]) -> ExitCode {
    if violations.is_empty() {
        println!("xtask {label}: {checked}, 0 violations");
        ExitCode::SUCCESS
    } else {
        for v in violations {
            eprintln!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
        }
        eprintln!("xtask {label}: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn analyze_cmd() -> ExitCode {
    let vs = analyze::run_all(&repo_root());
    report("analyze", "4 analyses", &vs)
}

fn lint() -> ExitCode {
    let root = repo_root();
    let read = |rel: &str| -> String {
        std::fs::read_to_string(root.join(rel)).unwrap_or_else(|e| panic!("read {rel}: {e}"))
    };

    let mut violations: Vec<Violation> = Vec::new();
    let mut files = 0usize;

    // 1. safety-comments over everything under rust/src/net/
    for rel in rs_files_under(&root, "rust/src/net") {
        files += 1;
        violations.extend(lint_safety_comments(&rel, &read(&rel)));
    }

    // 2. sync-facade over the migrated modules (epoll/uring exempt)
    for rel in FACADE_FILES {
        files += 1;
        violations.extend(lint_sync_facade(rel, &read(rel)));
    }

    // 3. codec-tags
    files += 2;
    violations.extend(lint_codec_tags(
        "rust/src/codec/mod.rs",
        &read("rust/src/codec/mod.rs"),
        &["get_wire", "get_paxos", "get_cmd", "get_phase"],
    ));
    violations.extend(lint_codec_tags(
        "rust/src/storage/mod.rs",
        &read("rust/src/storage/mod.rs"),
        &["get_record"],
    ));

    // 4 + 5. payload-alloc and unordered-iter over the protocol core
    for rel in rs_files_under(&root, "rust/src/protocols") {
        if rel.ends_with("tests.rs") {
            continue; // test-only file: allocation and order freedom
        }
        files += 1;
        let src = read(&rel);
        violations.extend(lint_payload_alloc(&rel, &src));
        violations.extend(lint_unordered_iter(&rel, &src));
    }

    // 6. exporter-coverage — stats structs vs the /metrics exporter
    files += 1;
    let export_src = read("rust/src/obs/export.rs");
    let coord_src = read("rust/src/coordinator/mod.rs");
    let net_src = read("rust/src/net/mod.rs");
    let storage_src = read("rust/src/storage/mod.rs");
    violations.extend(lint_exporter_coverage(
        &export_src,
        &[
            ("rust/src/coordinator/mod.rs", "CoordStats", coord_src.as_str()),
            ("rust/src/net/mod.rs", "NetStats", net_src.as_str()),
            ("rust/src/storage/mod.rs", "StorageStats", storage_src.as_str()),
        ],
    ));

    // 7. nemesis-reach — fault knobs stay confined to sim/tests
    for rel in rs_files_under(&root, "rust/src") {
        if rel.starts_with("rust/src/sim/") {
            continue; // the simulator owns the knobs by design
        }
        files += 1;
        violations.extend(lint_nemesis_reach(&rel, &read(&rel)));
    }

    report("lint", &format!("{files} files checked"), &violations)
}

/// Modules under the sync-facade rule. `net/epoll.rs` / `net/uring.rs`
/// are deliberately absent (kernel-shared atomics must stay `std`).
const FACADE_FILES: &[&str] = &[
    "rust/src/coordinator/mod.rs",
    "rust/src/net/mod.rs",
    "rust/src/storage/mod.rs",
    "rust/src/protocols/outbox.rs",
];

/// All `.rs` files under `root/rel`, as repo-relative `/`-separated
/// paths, sorted for deterministic output.
fn rs_files_under(root: &Path, rel: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut stack = vec![root.join(rel)];
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir).unwrap_or_else(|e| panic!("read_dir {dir:?}: {e}"));
        for entry in entries {
            let path = entry.expect("dir entry").path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path.strip_prefix(root).expect("under root");
                out.push(rel.to_string_lossy().replace('\\', "/"));
            }
        }
    }
    out.sort();
    out
}

// ---------------------------------------------------------------------
// line helpers
// ---------------------------------------------------------------------

/// Index of the first line opening a `#[cfg(test)]` /
/// `#[cfg(all(test, ...))]` region. Test modules sit at the bottom of
/// their files in this repo, so everything from here to EOF is skipped
/// by the rules that exempt test code.
fn test_mod_start(lines: &[&str]) -> usize {
    lines
        .iter()
        .position(|l| {
            let t = l.trim_start();
            t.starts_with("#[cfg(test)]") || t.starts_with("#[cfg(all(test")
        })
        .unwrap_or(lines.len())
}

/// Does `hay` contain `word` delimited by non-identifier characters?
fn has_word(hay: &str, word: &str) -> bool {
    let is_ident = |c: char| c.is_ascii_alphanumeric() || c == '_';
    let mut from = 0;
    while let Some(i) = hay[from..].find(word) {
        let start = from + i;
        let end = start + word.len();
        let pre_ok = start == 0 || !hay[..start].chars().next_back().is_some_and(is_ident);
        let post_ok = end == hay.len() || !hay[end..].chars().next().is_some_and(is_ident);
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

/// The identifier ending right before byte offset `end` (exclusive).
fn ident_before(line: &str, end: usize) -> &str {
    let bytes = line.as_bytes();
    let mut start = end;
    while start > 0 && (bytes[start - 1].is_ascii_alphanumeric() || bytes[start - 1] == b'_') {
        start -= 1;
    }
    &line[start..end]
}

/// Marker (e.g. `alloc-ok`, `unordered-ok`) on this line or the one above.
/// Runs on the *raw* lines: markers live in comments.
fn has_marker(lines: &[&str], idx: usize, marker: &str) -> bool {
    lines[idx].contains(marker) || (idx > 0 && lines[idx - 1].contains(marker))
}

// ---------------------------------------------------------------------
// rule 1: safety-comments
// ---------------------------------------------------------------------

fn lint_safety_comments(file: &str, src: &str) -> Vec<Violation> {
    let raw: Vec<&str> = src.lines().collect();
    let cv = lexer::code_view(src);
    let code: Vec<&str> = cv.lines().collect();
    let mut out = Vec::new();
    for (i, cl) in code.iter().enumerate() {
        if !has_word(cl, "unsafe") {
            continue;
        }
        if raw[i].contains("SAFETY:") {
            continue;
        }
        // walk the contiguous comment/attribute block directly above
        let mut documented = false;
        let mut j = i;
        while j > 0 {
            j -= 1;
            let t = raw[j].trim_start();
            if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!") {
                if t.contains("SAFETY:") {
                    documented = true;
                    break;
                }
            } else {
                break;
            }
        }
        if !documented {
            out.push(Violation {
                file: file.to_string(),
                line: i + 1,
                rule: "safety-comments",
                msg: "`unsafe` without a `// SAFETY:` comment on or above it".to_string(),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 2: sync-facade
// ---------------------------------------------------------------------

fn lint_sync_facade(file: &str, src: &str) -> Vec<Violation> {
    let raw: Vec<&str> = src.lines().collect();
    let cv = lexer::code_view(src);
    let code: Vec<&str> = cv.lines().collect();
    let limit = test_mod_start(&raw);
    let mut out = Vec::new();
    for (i, cl) in code.iter().enumerate().take(limit) {
        if cl.contains("std::sync::") || cl.contains("std::thread") {
            out.push(Violation {
                file: file.to_string(),
                line: i + 1,
                rule: "sync-facade",
                msg: "direct std::sync/std::thread use in a facade-migrated module \
                      (import from crate::sync so `--cfg loom` models it)"
                    .to_string(),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 3: codec-tags
// ---------------------------------------------------------------------

fn lint_codec_tags(file: &str, src: &str, fns: &[&str]) -> Vec<Violation> {
    let cv = lexer::code_view(src);
    let code: Vec<&str> = cv.lines().collect();
    let mut out = Vec::new();
    for name in fns {
        let needle = format!("fn {name}(");
        let Some(start) = code.iter().position(|l| l.contains(&needle)) else {
            out.push(Violation {
                file: file.to_string(),
                line: 1,
                rule: "codec-tags",
                msg: format!("decoder fn `{name}` not found (renamed? update xtask)"),
            });
            continue;
        };
        // brace-matched body of the fn
        let mut depth = 0i32;
        let mut opened = false;
        let mut tags: Vec<(u64, usize)> = Vec::new();
        for (i, line) in code.iter().enumerate().skip(start) {
            // `N => ...` match arms with an integer literal pattern
            let t = line.trim_start();
            let digits: String = t.chars().take_while(|c| c.is_ascii_digit()).collect();
            if !digits.is_empty() && t[digits.len()..].trim_start().starts_with("=>") {
                tags.push((digits.parse().unwrap(), i + 1));
            }
            for c in line.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
        }
        if tags.is_empty() {
            out.push(Violation {
                file: file.to_string(),
                line: start + 1,
                rule: "codec-tags",
                msg: format!("decoder fn `{name}` has no integer tag arms (rule gone stale?)"),
            });
        }
        let mut seen: Vec<u64> = Vec::new();
        for (tag, line) in tags {
            if seen.contains(&tag) {
                out.push(Violation {
                    file: file.to_string(),
                    line,
                    rule: "codec-tags",
                    msg: format!("duplicate wire tag {tag} in `{name}` shadows an earlier arm"),
                });
            } else {
                seen.push(tag);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 4: payload-alloc
// ---------------------------------------------------------------------

const ALLOC_PATTERNS: &[&str] = &[".to_vec()", ".to_owned()", "Vec::new()", "payload.clone()"];

fn lint_payload_alloc(file: &str, src: &str) -> Vec<Violation> {
    let raw: Vec<&str> = src.lines().collect();
    let cv = lexer::code_view(src);
    let code: Vec<&str> = cv.lines().collect();
    let limit = test_mod_start(&raw);
    let mut out = Vec::new();
    for (i, cl) in code.iter().enumerate().take(limit) {
        for pat in ALLOC_PATTERNS {
            if cl.contains(pat) && !has_marker(&raw, i, "alloc-ok") {
                out.push(Violation {
                    file: file.to_string(),
                    line: i + 1,
                    rule: "payload-alloc",
                    msg: format!(
                        "`{pat}` in protocol hot-path code (mark audited cold sites \
                         with `// alloc-ok: <reason>`)"
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 5: unordered-iter
// ---------------------------------------------------------------------

const ITER_METHODS: &[&str] =
    &[".iter()", ".iter_mut()", ".values()", ".values_mut()", ".keys()", ".drain(", ".into_iter()"];

/// Identifiers declared in this file with a `HashMap`/`FxHashMap` type
/// annotation or initialiser. `lines` must already be code-view lines.
fn hash_map_idents(lines: &[&str], limit: usize) -> Vec<String> {
    let mut idents = Vec::new();
    for line in lines.iter().take(limit) {
        let code = *line;
        // `ident: [pfx::]HashMap<...>` / `ident: [pfx::]FxHashMap<...>`
        let mut from = 0;
        while let Some(rel) = code[from..].find("HashMap<") {
            let at = from + rel;
            from = at + "HashMap<".len();
            // full type token (may be FxHashMap / crate::util::FxHashMap)
            let mut ty_start = at;
            let bytes = code.as_bytes();
            while ty_start > 0
                && (bytes[ty_start - 1].is_ascii_alphanumeric()
                    || bytes[ty_start - 1] == b'_'
                    || bytes[ty_start - 1] == b':')
            {
                ty_start -= 1;
            }
            let before = code[..ty_start].trim_end();
            if let Some(stripped) = before.strip_suffix(':') {
                let ident = ident_before(stripped, stripped.len());
                if !ident.is_empty() {
                    idents.push(ident.to_string());
                }
            }
        }
        // `ident = HashMap::new()` / `= FxHashMap::default()`
        for init in ["HashMap::new()", "HashMap::default()", "FxHashMap::default()"] {
            if let Some(at) = code.find(init) {
                let before = code[..at].trim_end();
                let before = before.strip_suffix("crate::util::").unwrap_or(before).trim_end();
                if let Some(stripped) = before.strip_suffix('=') {
                    let stripped = stripped.trim_end();
                    let ident = ident_before(stripped, stripped.len());
                    if !ident.is_empty() && ident != "mut" {
                        idents.push(ident.to_string());
                    }
                }
            }
        }
    }
    idents.sort();
    idents.dedup();
    idents
}

fn lint_unordered_iter(file: &str, src: &str) -> Vec<Violation> {
    let raw: Vec<&str> = src.lines().collect();
    let cv = lexer::code_view(src);
    let code: Vec<&str> = cv.lines().collect();
    let limit = test_mod_start(&raw);
    let tracked = hash_map_idents(&code, limit);
    let mut out = Vec::new();
    for (i, cl) in code.iter().enumerate().take(limit) {
        for m in ITER_METHODS {
            let mut from = 0;
            while let Some(rel) = cl[from..].find(m) {
                let at = from + rel;
                from = at + m.len();
                let ident = ident_before(cl, at);
                if tracked.iter().any(|t| t == ident) && !has_marker(&raw, i, "unordered-ok") {
                    out.push(Violation {
                        file: file.to_string(),
                        line: i + 1,
                        rule: "unordered-iter",
                        msg: format!(
                            "hash-order iteration `{ident}{m}..` in the protocol core \
                             (sort first, use BTreeMap, or mark the audited site with \
                             `// unordered-ok: <reason>`)"
                        ),
                    });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 6: exporter-coverage
// ---------------------------------------------------------------------

/// `(field, line)` for every `pub <field>: AtomicU64` inside the
/// brace-matched body of `pub struct <struct_name> { ... }`. Runs on the
/// code view so commented-out fields don't count. Empty if the struct is
/// missing (the caller turns that into a loud violation — a renamed
/// struct must not silently disable the rule).
fn atomic_counter_fields(src: &str, struct_name: &str) -> Vec<(String, usize)> {
    let cv = lexer::code_view(src);
    let code: Vec<&str> = cv.lines().collect();
    let needle = format!("pub struct {struct_name} {{");
    let Some(start) = code.iter().position(|l| l.contains(&needle)) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut opened = false;
    for (i, line) in code.iter().enumerate().skip(start) {
        if opened && depth > 0 && i > start {
            let t = line.trim_start();
            if let Some(rest) = t.strip_prefix("pub ") {
                if let Some((name, ty)) = rest.split_once(':') {
                    if ty.trim().trim_end_matches(',').ends_with("AtomicU64") {
                        out.push((name.trim().to_string(), i + 1));
                    }
                }
            }
        }
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            break;
        }
    }
    out
}

/// Every public `AtomicU64` counter of the listed stats structs must be
/// *read* in `obs/export.rs` (the field access `s.<name>.load(..)` —
/// mentioning the name in a comment or metric string does not count,
/// because `has_word` rejects `_`-joined occurrences and the export
/// source is scanned as a code view).
fn lint_exporter_coverage(
    export_src: &str,
    structs: &[(&str, &str, &str)], // (file, struct name, source)
) -> Vec<Violation> {
    let export_cv = lexer::code_view(export_src);
    let mut out = Vec::new();
    for (file, name, src) in structs {
        let fields = atomic_counter_fields(src, name);
        if fields.is_empty() {
            out.push(Violation {
                file: file.to_string(),
                line: 1,
                rule: "exporter-coverage",
                msg: format!("stats struct `{name}` not found or has no AtomicU64 fields (renamed? update xtask)"),
            });
            continue;
        }
        for (field, line) in fields {
            if !export_cv.lines().any(|l| has_word(l, &field)) {
                out.push(Violation {
                    file: file.to_string(),
                    line,
                    rule: "exporter-coverage",
                    msg: format!(
                        "`{name}.{field}` is not exported: add a counter_fn reading it \
                         in rust/src/obs/export.rs"
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 7: nemesis-reach
// ---------------------------------------------------------------------

/// The simulator's fault-injection surface: the [`wbam::sim::World`]
/// nemesis knobs plus the `MemWal` fault hook. Any of these reachable
/// from non-test code outside `rust/src/sim/` is a production path that
/// can partition its own cluster, skew its own clocks or tear its own
/// journal — exactly the capability the gate must keep fenced in.
const NEMESIS_KNOBS: &[&str] = &[
    "net_partition",
    "link_jitter",
    "link_dup",
    "link_reorder",
    "clock_skew",
    "gray_slow",
    "disk_slow",
    "disk_fault_at",
    "arm_fault",
];

/// Rule 7: nemesis knob names must not appear in non-`cfg(test)` code
/// outside the simulator; audited sites carry `// nemesis-ok: <reason>`
/// on the same line or the line above (markers live in comments, so
/// the check runs on the raw lines while matching on the code view).
fn lint_nemesis_reach(file: &str, src: &str) -> Vec<Violation> {
    let raw: Vec<&str> = src.lines().collect();
    let cv = lexer::code_view(src);
    let code: Vec<&str> = cv.lines().collect();
    let limit = test_mod_start(&raw);
    let mut out = Vec::new();
    for (i, cl) in code.iter().enumerate().take(limit) {
        for knob in NEMESIS_KNOBS {
            if has_word(cl, knob) && !has_marker(&raw, i, "nemesis-ok") {
                out.push(Violation {
                    file: file.to_string(),
                    line: i + 1,
                    rule: "nemesis-reach",
                    msg: format!(
                        "fault-injection knob `{knob}` reachable from non-test code \
                         (audited sites carry `// nemesis-ok: <reason>`)"
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// tests: every rule must fire on a minimal fixture violation and stay
// quiet on the corresponding clean fixture
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    // --- rule 1 ---

    #[test]
    fn safety_fires_on_undocumented_unsafe() {
        let src = "fn f() {\n    let p = unsafe { libc::epoll_create1(0) };\n}\n";
        let vs = lint_safety_comments("net/x.rs", src);
        assert_eq!(rules_of(&vs), ["safety-comments"]);
        assert_eq!(vs[0].line, 2);
    }

    #[test]
    fn safety_accepts_comment_above_or_inline() {
        let above = "// SAFETY: fd is owned\nlet p = unsafe { close(fd) };\n";
        assert!(lint_safety_comments("f", above).is_empty());
        let inline = "let p = unsafe { close(fd) }; // SAFETY: fd is owned\n";
        assert!(lint_safety_comments("f", inline).is_empty());
        // attribute between comment and item is allowed
        let attr = "// SAFETY: alloc contract upheld\n#[global_allocator]\nunsafe impl A for B {}\n";
        assert!(lint_safety_comments("f", attr).is_empty());
    }

    #[test]
    fn safety_ignores_unsafe_in_comments_and_words() {
        let src = "// this fn is unsafe to call twice\nlet unsafety = 1;\n";
        assert!(lint_safety_comments("f", src).is_empty());
    }

    #[test]
    fn safety_sees_through_raw_strings() {
        // a raw string containing `unsafe` must not fire, and an actual
        // `unsafe` after a string containing `//` must still fire
        let fake = "let doc = r#\"this mentions unsafe code\"#;\n";
        assert!(lint_safety_comments("f", fake).is_empty());
        let hidden = "let u = \"http://x\"; unsafe { go(u) };\n";
        assert_eq!(rules_of(&lint_safety_comments("f", hidden)), ["safety-comments"]);
    }

    // --- rule 2 ---

    #[test]
    fn facade_fires_on_direct_std_sync() {
        let src = "use std::sync::Mutex;\nfn f() { std::thread::spawn(|| {}); }\n";
        let vs = lint_sync_facade("coordinator/mod.rs", src);
        assert_eq!(rules_of(&vs), ["sync-facade", "sync-facade"]);
    }

    #[test]
    fn facade_skips_test_modules_and_comments() {
        let src = "use crate::sync::{Arc, Mutex};\n\
                   // std::thread::sleep is fine to *mention*\n\
                   #[cfg(test)]\n\
                   mod tests {\n    use std::sync::atomic::AtomicU16;\n}\n";
        assert!(lint_sync_facade("f", src).is_empty());
        let loom = "#[cfg(all(test, loom))]\nmod loom_tests {\n    use std::sync::atomic::AtomicU64;\n}\n";
        assert!(lint_sync_facade("f", loom).is_empty());
    }

    #[test]
    fn facade_ignores_pattern_inside_string_literal() {
        let src = "let msg = \"import from std::sync::Mutex instead\";\n";
        assert!(lint_sync_facade("f", src).is_empty());
    }

    // --- rule 3 ---

    #[test]
    fn codec_tags_fire_on_duplicate() {
        let src = "fn get_wire(d: &mut Dec) -> Result<Wire> {\n\
                       Ok(match d.u8()? {\n\
                           0 => Wire::A,\n\
                           1 => Wire::B,\n\
                           1 => Wire::C,\n\
                           _ => return Err(e),\n\
                       })\n\
                   }\n";
        let vs = lint_codec_tags("codec/mod.rs", src, &["get_wire"]);
        assert_eq!(rules_of(&vs), ["codec-tags"]);
        assert_eq!(vs[0].line, 5);
    }

    #[test]
    fn codec_tags_accept_unique_and_flag_missing_fn() {
        let src = "fn get_wire(d: &mut Dec) -> Result<Wire> {\n\
                       Ok(match d.u8()? {\n        0 => Wire::A,\n        1 => Wire::B,\n\
                           _ => return Err(e),\n    })\n}\n";
        assert!(lint_codec_tags("f", src, &["get_wire"]).is_empty());
        // a renamed decoder must fail loudly, not silently pass
        assert_eq!(rules_of(&lint_codec_tags("f", src, &["get_gone"])), ["codec-tags"]);
    }

    // --- rule 4 ---

    #[test]
    fn payload_alloc_fires_without_marker() {
        let src = "fn handle(&mut self) {\n    let copy = wire.payload.to_vec();\n}\n";
        let vs = lint_payload_alloc("protocols/x.rs", src);
        assert_eq!(rules_of(&vs), ["payload-alloc"]);
    }

    #[test]
    fn payload_alloc_respects_marker_and_tests() {
        let marked = "let buf = Vec::new(); // alloc-ok: constructor\n";
        assert!(lint_payload_alloc("f", marked).is_empty());
        let above = "// alloc-ok: split slow path\nlet chunk: Vec<Wire> = Vec::new();\n";
        assert!(lint_payload_alloc("f", above).is_empty());
        let test_mod = "#[cfg(test)]\nmod tests {\n    fn f() { let v = x.to_vec(); }\n}\n";
        assert!(lint_payload_alloc("f", test_mod).is_empty());
    }

    // --- rule 5 ---

    #[test]
    fn unordered_iter_fires_on_hashmap_iteration() {
        let src = "struct S { entries: HashMap<MsgId, Entry> }\n\
                   impl S {\n\
                       fn f(&self) { for e in self.entries.values() { use_(e); } }\n\
                   }\n";
        let vs = lint_unordered_iter("protocols/x.rs", src);
        assert_eq!(rules_of(&vs), ["unordered-iter"]);
        assert_eq!(vs[0].line, 3);
    }

    #[test]
    fn unordered_iter_tracks_fx_maps_and_initialisers() {
        let fx = "struct S { counts: FxHashMap<K, u32> }\nfn f(s: &S) { s.counts.keys(); }\n";
        assert_eq!(rules_of(&lint_unordered_iter("f", fx)), ["unordered-iter"]);
        let init = "let mut proposals = HashMap::new();\nfor p in proposals.drain() {}\n";
        assert_eq!(rules_of(&lint_unordered_iter("f", init)), ["unordered-iter"]);
    }

    #[test]
    fn unordered_iter_allows_btree_markers_and_other_idents() {
        let btree = "let merged: BTreeMap<MsgId, MsgState> = BTreeMap::new();\nmerged.values();\n";
        assert!(lint_unordered_iter("f", btree).is_empty());
        let marked = "struct S { m: HashMap<A, B> }\n\
                      fn f(s: &S) { s.m.values().max(); } // unordered-ok: max() fold\n";
        assert!(lint_unordered_iter("f", marked).is_empty());
        let other = "struct S { m: HashMap<A, B> }\nfn f(v: &[u8]) { v.iter(); }\n";
        assert!(lint_unordered_iter("f", other).is_empty());
    }

    // --- rule 6 ---

    #[test]
    fn exporter_coverage_fires_on_unexported_field() {
        let stats = "pub struct CoordStats {\n\
                         pub wires_in: AtomicU64,\n\
                         pub ghosts: AtomicU64,\n\
                     }\n";
        let export = "let s = stats.clone();\nreg.counter_fn(\"wbam_coord_wires_in_total\", \
                      \"d\", vec![], move || s.wires_in.load(Ordering::Relaxed));\n";
        let vs = lint_exporter_coverage(export, &[("coordinator/mod.rs", "CoordStats", stats)]);
        assert_eq!(rules_of(&vs), ["exporter-coverage"]);
        assert_eq!(vs[0].line, 3);
        assert!(vs[0].msg.contains("ghosts"), "{}", vs[0].msg);
    }

    #[test]
    fn exporter_coverage_clean_comment_blind_and_loud_on_missing_struct() {
        let stats = "pub struct NetStats {\n\
                         /// doc lines are ignored\n\
                         pub dropped_frames: AtomicU64,\n\
                         pub last_addr: Mutex<Option<SocketAddr>>,\n\
                     }\n";
        // a real field read satisfies the rule; non-AtomicU64 fields are out of scope
        let ok = "move || s.dropped_frames.load(Ordering::Relaxed)\n";
        assert!(lint_exporter_coverage(ok, &[("net/mod.rs", "NetStats", stats)]).is_empty());
        // a comment naming the field is NOT an export (code view blanks it)
        let comment_only = "// dropped_frames is handled elsewhere\n";
        let vs = lint_exporter_coverage(comment_only, &[("net/mod.rs", "NetStats", stats)]);
        assert_eq!(rules_of(&vs), ["exporter-coverage"]);
        // the metric-name string alone is NOT an export either
        let string_only = "reg.counter_fn(\"wbam_net_dropped_frames_total\", \"d\", vec![], zero);\n";
        let vs = lint_exporter_coverage(string_only, &[("net/mod.rs", "NetStats", stats)]);
        assert_eq!(rules_of(&vs), ["exporter-coverage"]);
        // a renamed struct must fail loudly, not silently pass
        let vs = lint_exporter_coverage(ok, &[("net/mod.rs", "GoneStats", stats)]);
        assert_eq!(rules_of(&vs), ["exporter-coverage"]);
    }

    // --- rule 7 ---

    #[test]
    fn nemesis_fires_on_unaudited_knob() {
        let src = "fn sabotage(w: &mut World) {\n    w.net_partition(&a, &b, 0, 10, false);\n}\n";
        let vs = lint_nemesis_reach("coordinator/mod.rs", src);
        assert_eq!(rules_of(&vs), ["nemesis-reach"]);
        assert_eq!(vs[0].line, 2);
        let disk = "fn f(s: &mut MemWal) { s.arm_fault(WalFault::Torn, 5_000); }\n";
        assert_eq!(rules_of(&lint_nemesis_reach("f", disk)), ["nemesis-reach"]);
    }

    #[test]
    fn nemesis_accepts_marker_tests_and_comments() {
        // audited site: marker on the line above
        let marked =
            "// nemesis-ok: recovery drill, gated behind an operator flag\nw.disk_fault_at(p, 0, WalFault::Torn, 1);\n";
        assert!(lint_nemesis_reach("f", marked).is_empty());
        // inline marker
        let inline = "w.clock_skew(p, 0, 5); // nemesis-ok: calibration shim\n";
        assert!(lint_nemesis_reach("f", inline).is_empty());
        // test modules are exempt
        let test_mod = "#[cfg(test)]\nmod tests {\n    fn f(w: &mut World) { w.gray_slow(Pid(0), 0, 9, 7); }\n}\n";
        assert!(lint_nemesis_reach("f", test_mod).is_empty());
        // knob names inside comments/strings are blanked by the code view
        let comment = "// clock_skew is applied when the timer is armed\nlet s = \"link_dup\";\n";
        assert!(lint_nemesis_reach("f", comment).is_empty());
        // longer identifiers sharing a prefix don't trip the word match
        let substr = "let link_jitter_docs = 1;\nfn net_partition_count() {}\n";
        assert!(lint_nemesis_reach("f", substr).is_empty());
    }

    // --- the gate passes on the real tree (the binary's own acceptance) ---

    #[test]
    fn clean_tree_has_no_violations() {
        let root = repo_root();
        assert!(root.join("rust/src/lib.rs").exists(), "repo root misdetected: {root:?}");
        // run the same scans main() runs, collecting everything
        let read = |rel: &str| std::fs::read_to_string(root.join(rel)).unwrap();
        let mut vs = Vec::new();
        for rel in rs_files_under(&root, "rust/src/net") {
            vs.extend(lint_safety_comments(&rel, &read(&rel)));
        }
        for rel in FACADE_FILES {
            vs.extend(lint_sync_facade(rel, &read(rel)));
        }
        vs.extend(lint_codec_tags(
            "rust/src/codec/mod.rs",
            &read("rust/src/codec/mod.rs"),
            &["get_wire", "get_paxos", "get_cmd", "get_phase"],
        ));
        vs.extend(lint_codec_tags(
            "rust/src/storage/mod.rs",
            &read("rust/src/storage/mod.rs"),
            &["get_record"],
        ));
        for rel in rs_files_under(&root, "rust/src/protocols") {
            if rel.ends_with("tests.rs") {
                continue;
            }
            let src = read(&rel);
            vs.extend(lint_payload_alloc(&rel, &src));
            vs.extend(lint_unordered_iter(&rel, &src));
        }
        let export_src = read("rust/src/obs/export.rs");
        let coord_src = read("rust/src/coordinator/mod.rs");
        let net_src = read("rust/src/net/mod.rs");
        let storage_src = read("rust/src/storage/mod.rs");
        vs.extend(lint_exporter_coverage(
            &export_src,
            &[
                ("rust/src/coordinator/mod.rs", "CoordStats", coord_src.as_str()),
                ("rust/src/net/mod.rs", "NetStats", net_src.as_str()),
                ("rust/src/storage/mod.rs", "StorageStats", storage_src.as_str()),
            ],
        ));
        for rel in rs_files_under(&root, "rust/src") {
            if rel.starts_with("rust/src/sim/") {
                continue;
            }
            vs.extend(lint_nemesis_reach(&rel, &read(&rel)));
        }
        assert!(vs.is_empty(), "clean-tree violations: {vs:#?}");
    }
}
