//! A minimal, dependency-free Rust lexer for the static analyzer.
//!
//! This is *not* a full Rust lexer — it is exactly the subset the
//! analyses in [`crate::analyze`] need: it classifies every byte of a
//! source file into identifiers, numbers, string/char literals,
//! lifetimes, punctuation, or comments, with correct handling of the
//! cases that break naive line-based linting:
//!
//! * nested block comments (`/* a /* b */ c */`),
//! * raw strings with hash fences (`r#".."#`) whose bodies may contain
//!   `//`, quotes, or braces,
//! * byte strings / byte chars (`b".."`, `b'x'`),
//! * escaped quotes and line-continuation backslashes inside strings,
//! * the `'a` lifetime vs `'a'` char-literal ambiguity.
//!
//! Every token records its 1-based start line and its byte span in the
//! original source, so analyses can report precise locations and
//! [`code_view`] can blank out non-code bytes while preserving both the
//! byte length and every newline position of the input.
//!
//! The lexer works on bytes. Multi-byte UTF-8 sequences only ever appear
//! inside comments and literals in this tree, but unknown non-ASCII
//! bytes in code position are still consumed as a single whole-sequence
//! punct token so spans never split a character.

/// Token classification. Comments are real tokens (not skipped) so the
/// parser can implement marker lookup (`// lock-ok: ...`) and so
/// [`code_view`] knows which byte ranges to blank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
    LineComment,
    BlockComment,
}

/// One lexed token: classification, verbatim text, 1-based start line,
/// and `[start, end)` byte span in the source.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
    pub start: usize,
    pub end: usize,
}

fn is_id_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_id(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Tokenize `src`. Whitespace is dropped; everything else (including
/// comments) becomes a token. Unterminated literals/comments extend to
/// end of input rather than failing — the analyzer must degrade
/// gracefully on any input.
pub fn lex(src: &str) -> Vec<Tok> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    let push = |toks: &mut Vec<Tok>, kind: Kind, start: usize, end: usize, sl: usize| {
        toks.push(Tok {
            kind,
            text: String::from_utf8_lossy(&b[start..end]).into_owned(),
            line: sl,
            start,
            end,
        });
    };

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        let start = i;
        let sl = line;
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            push(&mut toks, Kind::LineComment, start, i, sl);
            continue;
        }
        // nested block comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            push(&mut toks, Kind::BlockComment, start, i, sl);
            continue;
        }
        // raw / byte strings: r".."  r#".."#  br".."  b".."  b'x'
        if c == b'r' || c == b'b' {
            let mut j = i;
            if b[j] == b'b' {
                j += 1;
            }
            if j < n && b[j] == b'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && b[k] == b'#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == b'"' {
                    i = k + 1;
                    while i < n {
                        if b[i] == b'\n' {
                            line += 1;
                            i += 1;
                            continue;
                        }
                        if b[i] == b'"' {
                            let mut h = 0usize;
                            let mut m = i + 1;
                            while m < n && b[m] == b'#' && h < hashes {
                                h += 1;
                                m += 1;
                            }
                            if h == hashes {
                                i = m;
                                break;
                            }
                        }
                        i += 1;
                    }
                    push(&mut toks, Kind::Str, start, i, sl);
                    continue;
                }
            }
            if b[i] == b'b' && i + 1 < n && b[i + 1] == b'"' {
                i += 2;
                while i < n {
                    if b[i] == b'\\' {
                        if i + 1 < n && b[i + 1] == b'\n' {
                            line += 1;
                        }
                        i += 2;
                    } else if b[i] == b'"' {
                        i += 1;
                        break;
                    } else {
                        if b[i] == b'\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
                push(&mut toks, Kind::Str, start, i.min(n), sl);
                continue;
            }
            if b[i] == b'b' && i + 1 < n && b[i + 1] == b'\'' {
                i += 2;
                if i < n && b[i] == b'\\' {
                    i += 2;
                }
                while i < n && b[i] != b'\'' {
                    i += 1;
                }
                i += 1;
                push(&mut toks, Kind::Char, start, i.min(n), sl);
                continue;
            }
            // otherwise: plain identifier starting with r/b; fall through
        }
        if c == b'"' {
            i += 1;
            while i < n {
                if b[i] == b'\\' {
                    if i + 1 < n && b[i + 1] == b'\n' {
                        line += 1;
                    }
                    i += 2;
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            push(&mut toks, Kind::Str, start, i.min(n), sl);
            continue;
        }
        if c == b'\'' {
            // `'ident` not followed by `'` is a lifetime; `'x'` is a char
            if i + 1 < n && (b[i + 1].is_ascii_alphabetic() || b[i + 1] == b'_') {
                let mut j = i + 2;
                while j < n && is_id(b[j]) {
                    j += 1;
                }
                if j >= n || b[j] != b'\'' {
                    i = j;
                    push(&mut toks, Kind::Lifetime, start, i, sl);
                    continue;
                }
            }
            i += 1;
            if i < n && b[i] == b'\\' {
                i += 2;
            }
            while i < n && b[i] != b'\'' {
                i += 1;
            }
            i += 1;
            push(&mut toks, Kind::Char, start, i.min(n), sl);
            continue;
        }
        if c.is_ascii_digit() {
            i += 1;
            let mut seen_dot = false;
            while i < n {
                let d = b[i];
                if is_id(d) {
                    i += 1;
                } else if d == b'.' && !seen_dot && i + 1 < n && b[i + 1].is_ascii_digit() {
                    seen_dot = true;
                    i += 1;
                } else {
                    break;
                }
            }
            push(&mut toks, Kind::Num, start, i, sl);
            continue;
        }
        if is_id_start(c) {
            i += 1;
            while i < n && is_id(b[i]) {
                i += 1;
            }
            push(&mut toks, Kind::Ident, start, i, sl);
            continue;
        }
        // punctuation; a non-ASCII lead byte consumes its whole sequence
        i += 1;
        while i < n && b[i] >= 0x80 && b[i] < 0xC0 && c >= 0x80 {
            i += 1;
        }
        push(&mut toks, Kind::Punct, start, i, sl);
    }
    toks
}

/// Return `src` with every byte of comments and string/char literals
/// replaced by a space (newlines kept), preserving length and line
/// structure. Line-oriented pattern checks run on this view so that
/// `// .to_vec()` in a comment or `"std::sync::"` in a string can never
/// fire — and so that code *after* a `//` embedded in a string literal
/// is still seen.
pub fn code_view(src: &str) -> String {
    let mut out: Vec<u8> = src.as_bytes().to_vec();
    for t in lex(src) {
        match t.kind {
            Kind::LineComment | Kind::BlockComment | Kind::Str | Kind::Char => {
                for k in t.start..t.end.min(out.len()) {
                    if out[k] != b'\n' {
                        out[k] = b' ';
                    }
                }
            }
            _ => {}
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_nums_punct() {
        let ks = kinds("let x2 = 41.5 + y;");
        assert_eq!(
            ks,
            vec![
                (Kind::Ident, "let".into()),
                (Kind::Ident, "x2".into()),
                (Kind::Punct, "=".into()),
                (Kind::Num, "41.5".into()),
                (Kind::Punct, "+".into()),
                (Kind::Ident, "y".into()),
                (Kind::Punct, ";".into()),
            ]
        );
    }

    #[test]
    fn nested_block_comment_is_one_token() {
        let ks = kinds("a /* x /* y */ z */ b");
        assert_eq!(ks.len(), 3);
        assert_eq!(ks[1], (Kind::BlockComment, "/* x /* y */ z */".into()));
        assert_eq!(ks[2].1, "b");
    }

    #[test]
    fn raw_string_with_hashes_and_fake_comment() {
        let src = "let s = r#\"// not \"a\" comment\"#; x";
        let ks = kinds(src);
        assert_eq!(ks[3], (Kind::Str, "r#\"// not \"a\" comment\"#".into()));
        assert_eq!(ks.last().unwrap().1, "x");
    }

    #[test]
    fn byte_string_and_byte_char() {
        let ks = kinds("b\"ab\\\"c\" b'x' b'\\''");
        assert_eq!(ks[0], (Kind::Str, "b\"ab\\\"c\"".into()));
        assert_eq!(ks[1], (Kind::Char, "b'x'".into()));
        assert_eq!(ks[2], (Kind::Char, "b'\\''".into()));
    }

    #[test]
    fn lifetime_vs_char() {
        let ks = kinds("&'a T; 'x'; '\\n'; 'long_life");
        let got: Vec<Kind> = ks.iter().map(|(k, _)| *k).collect();
        assert!(got.contains(&Kind::Lifetime));
        assert_eq!(ks[1], (Kind::Lifetime, "'a".into()));
        assert_eq!(ks[4], (Kind::Char, "'x'".into()));
        assert_eq!(ks[6], (Kind::Char, "'\\n'".into()));
        assert_eq!(ks.last().unwrap(), &(Kind::Lifetime, "'long_life".into()));
    }

    #[test]
    fn char_literal_with_brace_does_not_confuse_depth() {
        let ks = kinds("match c { '{' => 1, _ => 2 }");
        let braces: Vec<&str> = ks
            .iter()
            .filter(|(k, t)| *k == Kind::Punct && (t == "{" || t == "}"))
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(braces, vec!["{", "}"], "'{{' must lex as a char literal");
    }

    #[test]
    fn string_with_line_continuation_counts_lines() {
        let src = "let a = \"one\\\ntwo\";\nlet b = 1;";
        let toks = lex(src);
        let b_tok = toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn code_view_blanks_opaque_preserving_layout() {
        let src = "foo(); // .to_vec()\nlet s = \"std::sync::x\";\n/* a\nb */ bar();";
        let cv = code_view(src);
        assert_eq!(cv.len(), src.len());
        let nl = |s: &str| {
            s.bytes()
                .enumerate()
                .filter(|(_, c)| *c == b'\n')
                .map(|(i, _)| i)
                .collect::<Vec<_>>()
        };
        assert_eq!(nl(&cv), nl(src));
        assert!(!cv.contains(".to_vec"));
        assert!(!cv.contains("std::sync"));
        assert!(cv.contains("foo"));
        assert!(cv.contains("bar"));
    }

    #[test]
    fn code_view_reveals_code_after_string_with_slashes() {
        // the old line-based `code_part` truncated at the `//` inside the
        // string, hiding `evil.to_vec()` from every rule
        let src = "let u = \"http://x\"; evil.to_vec();";
        let cv = code_view(src);
        assert!(cv.contains("evil.to_vec()"));
    }

    #[test]
    fn spans_are_exact_source_slices() {
        let src = "fn f(x: &'a str) -> u32 { x.len() as u32 } // tail";
        for t in lex(src) {
            assert_eq!(&src[t.start..t.end], t.text);
        }
    }

    // ---- property test: random fragment assembly -------------------------
    //
    // xtask is dependency-free (it cannot use the wbam crate's util::prop),
    // so this carries its own tiny deterministic xorshift generator.

    struct XorShift(u64);

    impl XorShift {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
        fn below(&mut self, n: usize) -> usize {
            (self.next() % n as u64) as usize
        }
    }

    /// Fragments: (text, opaque). Opaque fragments contain the sentinel
    /// `ZXQ` (must vanish from code_view); code fragments contain `KEEP`
    /// idents (must survive).
    const FRAGMENTS: &[(&str, bool)] = &[
        ("// ZXQ unsafe .to_vec()\n", true),
        ("/* ZXQ std::sync:: /* nested ZXQ */ tail */", true),
        ("\"ZXQ \\\" escaped\"", true),
        ("r#\"ZXQ // \"not\" a comment\"#", true),
        ("b\"ZXQ bytes\"", true),
        ("'\\''", true),
        ("'{'", true),
        ("let KEEP_x = 1;", false),
        ("KEEP_y.lock().unwrap();", false),
        ("fn KEEP_f<'a>(v: &'a [u8]) -> usize { v.len() }", false),
        ("match KEEP_z { 0 => {} _ => {} }", false),
    ];

    fn count(hay: &str, needle: &str) -> usize {
        hay.match_indices(needle).count()
    }

    #[test]
    fn prop_lex_covers_and_code_view_filters() {
        let mut rng = XorShift(0x9e3779b97f4a7c15);
        for _case in 0..200 {
            let mut src = String::new();
            let parts = 1 + rng.below(20);
            for _ in 0..parts {
                src.push_str(FRAGMENTS[rng.below(FRAGMENTS.len())].0);
                src.push(if rng.below(3) == 0 { '\n' } else { ' ' });
            }
            let toks = lex(&src);
            // spans: in-bounds, ordered, non-overlapping, exact slices
            let mut prev_end = 0usize;
            for t in &toks {
                assert!(t.start >= prev_end, "overlap in {src:?}");
                assert!(t.end <= src.len());
                assert!(t.end > t.start, "empty token in {src:?}");
                assert_eq!(&src[t.start..t.end], t.text);
                prev_end = t.end;
            }
            // every byte outside tokens is whitespace
            let mut covered = vec![false; src.len()];
            for t in &toks {
                for c in covered.iter_mut().take(t.end).skip(t.start) {
                    *c = true;
                }
            }
            for (k, c) in src.bytes().enumerate() {
                if !covered[k] {
                    assert!(
                        c == b' ' || c == b'\t' || c == b'\r' || c == b'\n',
                        "uncovered non-ws byte {c} in {src:?}"
                    );
                }
            }
            // code_view: same length, same newlines, opaque gone, code kept
            let cv = code_view(&src);
            assert_eq!(cv.len(), src.len());
            let nls = |s: &str| s.bytes().filter(|c| *c == b'\n').count();
            assert_eq!(nls(&cv), nls(&src));
            assert_eq!(count(&cv, "ZXQ"), 0, "opaque text leaked in {src:?}");
            assert_eq!(count(&cv, "KEEP"), count(&src, "KEEP"), "code text lost in {src:?}");
        }
    }
}
