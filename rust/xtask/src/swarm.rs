//! `cargo xtask swarm` — the deterministic nemesis campaign CLI.
//!
//! Thin argv/artifact shell around [`wbam::sim::swarm`]: generation,
//! execution, checking and minimization all live in the library (shared
//! with `rust/tests/swarm.rs`), so the CLI and the test entry point can
//! never drift apart.
//!
//! ```text
//! cargo xtask swarm --schedules 1000 --seed 1 [--out target/swarm]
//! cargo xtask swarm --repro failure-17.json
//! ```
//!
//! Campaign mode runs `--schedules` generated schedules under the
//! strict invariant suite and prints a deterministic summary hash (two
//! identical invocations print identical hashes — the acceptance pin).
//! Every failure is saved under `--out`: the schedule as JSON, the
//! flight-recorder tail, and the ddmin-minimized schedule. With
//! `WBAM_SMOKE=1` the schedule count is capped at 32 (the PR-gate
//! smoke). Repro mode replays a saved JSON schedule, reports whether
//! the failure reproduces, and writes `<file>.min.json`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use wbam::sim::nemesis::NemesisSchedule;
use wbam::sim::swarm::{campaign_with, minimize, run as run_schedule, Failure};

pub fn run(args: &[String]) -> ExitCode {
    let mut schedules: u64 = 1000;
    let mut seed: u64 = 1;
    let mut repro: Option<PathBuf> = None;
    let mut out_dir = PathBuf::from("target/swarm");

    let mut i = 0;
    while i < args.len() {
        let need = |what: &str| -> Result<&String, String> {
            args.get(i + 1).ok_or_else(|| format!("{what} needs a value"))
        };
        let r = match args[i].as_str() {
            "--schedules" => need("--schedules").and_then(|v| {
                v.parse().map(|n| schedules = n).map_err(|e| format!("--schedules: {e}"))
            }),
            "--seed" => need("--seed")
                .and_then(|v| v.parse().map(|n| seed = n).map_err(|e| format!("--seed: {e}"))),
            "--repro" => need("--repro").map(|v| repro = Some(PathBuf::from(v))),
            "--out" => need("--out").map(|v| out_dir = PathBuf::from(v)),
            other => Err(format!("unknown flag {other:?}")),
        };
        if let Err(e) = r {
            eprintln!("xtask swarm: {e}");
            eprintln!(
                "usage: cargo xtask swarm [--schedules N] [--seed S] [--out DIR] | --repro FILE"
            );
            return ExitCode::FAILURE;
        }
        i += 2;
    }

    if let Some(path) = repro {
        return repro_mode(&path);
    }

    // PR-gate smoke: same env convention as the bench smokes
    if std::env::var("WBAM_SMOKE").is_ok() {
        schedules = schedules.min(32);
    }
    campaign_mode(schedules, seed, &out_dir)
}

fn campaign_mode(schedules: u64, seed: u64, out_dir: &Path) -> ExitCode {
    println!("swarm: running {schedules} schedules from seed {seed}");
    let progress_every = (schedules / 10).max(1);
    let c = campaign_with(schedules, seed, |i, o| {
        if (i + 1) % progress_every == 0 {
            println!("swarm: {}/{} schedules", i + 1, schedules);
        }
        if o.failed() {
            eprintln!("swarm: schedule {i} FAILED: {}", o.violations.join("; "));
        }
    });

    for f in &c.failures {
        if let Err(e) = save_failure(out_dir, f) {
            eprintln!("swarm: could not save failure artifacts: {e}");
            return ExitCode::FAILURE;
        }
    }

    println!(
        "swarm: {} schedules, {} failures, summary-hash 0x{:016x}",
        c.schedules,
        c.failures.len(),
        c.summary
    );
    if c.failures.is_empty() {
        ExitCode::SUCCESS
    } else {
        eprintln!("swarm: failing schedules + flight dumps + minimized reproducers in {out_dir:?}");
        ExitCode::FAILURE
    }
}

/// Save one failure's artifact set: the schedule, its flight tail, and
/// the minimized reproducer.
fn save_failure(out_dir: &Path, f: &Failure) -> std::io::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let stem = out_dir.join(format!("failure-{}", f.index));
    std::fs::write(stem.with_extension("json"), f.schedule.to_json())?;
    std::fs::write(
        stem.with_extension("flight.txt"),
        format!("{}\n\n{}", f.outcome.violations.join("\n"), f.outcome.flight),
    )?;
    let min = minimize(&f.schedule);
    std::fs::write(stem.with_extension("min.json"), min.to_json())?;
    eprintln!(
        "swarm: schedule {} minimized {} -> {} events ({:?})",
        f.index,
        f.schedule.events.len(),
        min.events.len(),
        stem.with_extension("min.json")
    );
    Ok(())
}

fn repro_mode(path: &Path) -> ExitCode {
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("xtask swarm: read {path:?}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let sched = match NemesisSchedule::from_json(&src) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("xtask swarm: parse {path:?}: {e}");
            return ExitCode::FAILURE;
        }
    };
    println!(
        "swarm: replaying {path:?} (seed {}, {} events)",
        sched.seed,
        sched.events.len()
    );
    let o = run_schedule(&sched);
    if !o.failed() {
        eprintln!("swarm: schedule did NOT reproduce a failure");
        return ExitCode::FAILURE;
    }
    println!("swarm: reproduced {} violation(s):", o.violations.len());
    for v in &o.violations {
        println!("  {v}");
    }
    if !o.flight.is_empty() {
        println!("--- flight recorder tail ---\n{}", o.flight);
    }
    let min = minimize(&sched);
    let min_path = path.with_extension("min.json");
    if let Err(e) = std::fs::write(&min_path, min.to_json()) {
        eprintln!("xtask swarm: write {min_path:?}: {e}");
        return ExitCode::FAILURE;
    }
    println!(
        "swarm: minimized {} -> {} events, saved to {min_path:?}",
        sched.events.len(),
        min.events.len()
    );
    ExitCode::SUCCESS
}
