//! Item-level parser on top of [`crate::lexer`].
//!
//! Extracts just enough structure for the protocol analyses: the list of
//! functions (with `impl`-qualified names, body token ranges, and
//! whether they live under `#[cfg(test)]` / `#[test]`), `match` arms,
//! call edges, and `Head::Variant` path occurrences. It is deliberately
//! permissive — unknown constructs are skipped, never fatal — because
//! the analyzer must keep working as the tree grows.

use crate::lexer::{lex, Kind, Tok};
use std::collections::BTreeMap;

/// One `fn` item found in a file.
#[derive(Clone, Debug)]
pub struct FnInfo {
    /// bare name (`on_wire`)
    pub name: String,
    /// `impl`-qualified name (`WbNode::on_wire`) when inside an impl
    pub qname: String,
    /// 1-based line of the `fn` keyword
    pub line: usize,
    /// token index range of the body, exclusive of the braces
    pub body: (usize, usize),
    /// true when under `#[test]`, `#[cfg(test)] mod`, or a test impl
    pub in_test: bool,
}

/// A lexed + item-scanned source file. `toks` holds only code tokens;
/// comments are kept separately for marker lookup.
pub struct ParsedFile {
    pub path: String,
    pub toks: Vec<Tok>,
    pub comments: Vec<Tok>,
    pub fns: Vec<FnInfo>,
}

impl ParsedFile {
    pub fn parse(path: &str, src: &str) -> ParsedFile {
        let all = lex(src);
        let mut toks = Vec::new();
        let mut comments = Vec::new();
        for t in all {
            match t.kind {
                Kind::LineComment | Kind::BlockComment => comments.push(t),
                _ => toks.push(t),
            }
        }
        let fns = scan_items(&toks);
        ParsedFile { path: path.to_string(), toks, comments, fns }
    }

    /// True when `marker` appears in a comment on `line` itself or
    /// anywhere in the contiguous comment block ending on the line
    /// directly above. Multi-line block comments cover all their lines.
    pub fn has_marker(&self, line: usize, marker: &str) -> bool {
        let mut by_line: BTreeMap<usize, bool> = BTreeMap::new();
        for c in &self.comments {
            let span = c.text.matches('\n').count();
            let hit = c.text.contains(marker);
            for k in c.line..=c.line + span {
                let e = by_line.entry(k).or_insert(false);
                *e = *e || hit;
            }
        }
        if by_line.get(&line).copied().unwrap_or(false) {
            return true;
        }
        let mut k = line.saturating_sub(1);
        while k > 0 {
            match by_line.get(&k) {
                Some(true) => return true,
                Some(false) => k -= 1,
                None => break,
            }
        }
        false
    }
}

/// True when token `i` and `i + 1` are byte-adjacent (no whitespace).
pub fn is_adj(toks: &[Tok], i: usize) -> bool {
    toks[i].end == toks[i + 1].start
}

/// `toks[open_idx]` must be `{`; returns the index of the matching `}`
/// (or `toks.len()` when unbalanced).
pub fn matching_brace(toks: &[Tok], open_idx: usize) -> usize {
    let mut d = 0i64;
    let mut i = open_idx;
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == Kind::Punct {
            if t.text == "{" {
                d += 1;
            } else if t.text == "}" {
                d -= 1;
                if d == 0 {
                    return i;
                }
            }
        }
        i += 1;
    }
    toks.len()
}

/// `toks[i]` must be `#`. Returns `(index past the attribute, inner
/// token range)` for `#[...]` / `#![...]`, or `None` if not an
/// attribute.
fn attr_end(toks: &[Tok], i: usize) -> Option<(usize, (usize, usize))> {
    let mut j = i + 1;
    if j < toks.len() && toks[j].kind == Kind::Punct && toks[j].text == "!" {
        j += 1;
    }
    if j >= toks.len() || toks[j].text != "[" {
        return None;
    }
    let mut d = 0i64;
    let mut k = j;
    while k < toks.len() {
        if toks[k].kind == Kind::Punct {
            if toks[k].text == "[" {
                d += 1;
            } else if toks[k].text == "]" {
                d -= 1;
                if d == 0 {
                    return Some((k + 1, (j + 1, k)));
                }
            }
        }
        k += 1;
    }
    Some((toks.len(), (j + 1, toks.len())))
}

/// `toks[i]` must be `impl`. Returns `(self-type name, index of the body
/// '{')`. Handles generics (`impl<T: Ord> Map<T>`), trait impls
/// (`impl Trait for Type` — the type after `for` wins), and `where`
/// clauses (idents after `where` never shadow the type).
fn impl_type(toks: &[Tok], i: usize) -> (String, usize) {
    let mut j = i + 1;
    // skip leading generic params, minding `->` inside them
    if j < toks.len() && toks[j].text == "<" {
        let mut d = 0i64;
        while j < toks.len() {
            let t = &toks[j];
            if t.text == "<" {
                d += 1;
            } else if t.text == ">" && !(j > 0 && toks[j - 1].text == "-" && is_adj(toks, j - 1)) {
                d -= 1;
                if d == 0 {
                    j += 1;
                    break;
                }
            }
            j += 1;
        }
    }
    let mut name = String::new();
    let mut d = 0i64;
    let mut frozen = false;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == Kind::Punct {
            if t.text == "<" {
                d += 1;
            } else if t.text == ">" && !(j > 0 && toks[j - 1].text == "-" && is_adj(toks, j - 1)) {
                d -= 1;
            } else if t.text == "{" && d <= 0 {
                return (name, j);
            }
        } else if t.kind == Kind::Ident && d <= 0 && !frozen {
            if t.text == "for" {
                name.clear();
            } else if t.text == "where" {
                frozen = true;
            } else if !matches!(t.text.as_str(), "dyn" | "unsafe" | "const" | "mut") {
                name = t.text.clone();
            }
        }
        j += 1;
    }
    (name, toks.len())
}

const ITEM_KEYWORDS: &[&str] =
    &["struct", "enum", "trait", "union", "const", "static", "type", "use", "extern"];

/// Walk the token stream tracking brace depth and an `impl`/`mod`
/// context stack; emit every `fn` with its qualified name, body range,
/// and test-ness.
fn scan_items(toks: &[Tok]) -> Vec<FnInfo> {
    let mut fns = Vec::new();
    let mut i = 0usize;
    let mut depth = 0i64;
    // (depth at open, impl type if any, is_test)
    let mut ctx: Vec<(i64, Option<String>, bool)> = Vec::new();
    let mut pending_test = false;

    let in_test = |ctx: &[(i64, Option<String>, bool)]| ctx.iter().any(|c| c.2);
    let cur_impl = |ctx: &[(i64, Option<String>, bool)]| {
        ctx.iter().rev().find_map(|c| c.1.clone())
    };

    while i < toks.len() {
        let t = &toks[i];
        if t.kind == Kind::Punct {
            if t.text == "{" {
                depth += 1;
            } else if t.text == "}" {
                depth -= 1;
                while ctx.last().is_some_and(|c| c.0 == depth) {
                    ctx.pop();
                }
            } else if t.text == "#" {
                if let Some((end, (a, b))) = attr_end(toks, i) {
                    if toks[a..b].iter().any(|k| k.kind == Kind::Ident && k.text == "test") {
                        pending_test = true;
                    }
                    i = end;
                    continue;
                }
            }
            i += 1;
            continue;
        }
        if t.kind == Kind::Ident {
            match t.text.as_str() {
                "impl" => {
                    let (ty, brace) = impl_type(toks, i);
                    if brace < toks.len() {
                        let test = pending_test || in_test(&ctx);
                        ctx.push((depth, if ty.is_empty() { None } else { Some(ty) }, test));
                        pending_test = false;
                        depth += 1;
                        i = brace + 1;
                        continue;
                    }
                    i += 1;
                    continue;
                }
                "mod" => {
                    let mut j = i + 1;
                    while j < toks.len() && toks[j].text != "{" && toks[j].text != ";" {
                        j += 1;
                    }
                    if j < toks.len() && toks[j].text == "{" {
                        let test = pending_test || in_test(&ctx);
                        ctx.push((depth, None, test));
                        pending_test = false;
                        depth += 1;
                        i = j + 1;
                        continue;
                    }
                    pending_test = false;
                    i = j + 1;
                    continue;
                }
                "fn" => {
                    let name = if i + 1 < toks.len() && toks[i + 1].kind == Kind::Ident {
                        toks[i + 1].text.clone()
                    } else {
                        String::new()
                    };
                    let mut j = i + 2;
                    while j < toks.len() && toks[j].text != "{" && toks[j].text != ";" {
                        j += 1;
                    }
                    if j < toks.len() && toks[j].text == "{" {
                        let close = matching_brace(toks, j);
                        let qname = match cur_impl(&ctx) {
                            Some(imp) => format!("{imp}::{name}"),
                            None => name.clone(),
                        };
                        fns.push(FnInfo {
                            name,
                            qname,
                            line: t.line,
                            body: (j + 1, close),
                            in_test: pending_test || in_test(&ctx),
                        });
                        pending_test = false;
                        depth += 1;
                        i = j + 1;
                        continue;
                    }
                    pending_test = false;
                    i = j + 1;
                    continue;
                }
                w if ITEM_KEYWORDS.contains(&w) => {
                    pending_test = false;
                    i += 1;
                    continue;
                }
                _ => {
                    i += 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    fns
}

/// One arm of a `match`: pattern and body token ranges plus start line.
pub struct Arm {
    pub pat: (usize, usize),
    pub body: (usize, usize),
    #[allow(dead_code)]
    pub line: usize,
}

/// `toks[match_idx]` must be the `match` ident. Returns its arms
/// (pattern range, body range). `limit` bounds the scan (typically the
/// enclosing fn body end).
pub fn match_arms(toks: &[Tok], match_idx: usize, limit: usize) -> Vec<Arm> {
    let n = limit.min(toks.len());
    let mut i = match_idx + 1;
    let mut pd = 0i64;
    while i < n {
        let t = toks[i].text.as_str();
        if t == "(" || t == "[" {
            pd += 1;
        } else if t == ")" || t == "]" {
            pd -= 1;
        } else if t == "{" && pd == 0 {
            break;
        }
        i += 1;
    }
    if i >= n {
        return Vec::new();
    }
    let open_b = i;
    let close = matching_brace(toks, open_b).min(n);
    let mut arms = Vec::new();
    let mut j = open_b + 1;
    while j < close {
        let pat_start = j;
        let mut d = 0i64;
        while j < close {
            let t = toks[j].text.as_str();
            if t == "(" || t == "[" || t == "{" {
                d += 1;
            } else if t == ")" || t == "]" || t == "}" {
                d -= 1;
            } else if t == "=" && d == 0 && j + 1 < close && toks[j + 1].text == ">" && is_adj(toks, j) {
                break;
            }
            j += 1;
        }
        if j >= close {
            break;
        }
        let pat = (pat_start, j);
        let line = toks[pat_start].line;
        j += 2; // past =>
        let body_start = j;
        let body;
        if j < close && toks[j].text == "{" {
            let bclose = matching_brace(toks, j).min(close);
            body = (body_start, bclose + 1);
            j = bclose + 1;
            if j < close && toks[j].text == "," {
                j += 1;
            }
        } else {
            let mut d = 0i64;
            while j < close {
                let t = toks[j].text.as_str();
                if t == "(" || t == "[" || t == "{" {
                    d += 1;
                } else if t == ")" || t == "]" || t == "}" {
                    d -= 1;
                } else if t == "," && d == 0 {
                    break;
                }
                j += 1;
            }
            body = (body_start, j);
            if j < close {
                j += 1;
            }
        }
        arms.push(Arm { pat, body, line });
    }
    arms
}

const CALL_KEYWORDS: &[&str] =
    &["if", "while", "for", "match", "return", "loop", "unsafe", "else", "move", "in", "as", "box"];

/// `(callee name, token index)` for every `name(`-shaped call in the
/// token range. Purely name-based: method calls and free fns alike.
pub fn calls_in(toks: &[Tok], rng: (usize, usize)) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let end = rng.1.min(toks.len());
    if end == 0 {
        return out;
    }
    for i in rng.0..end.saturating_sub(1) {
        let t = &toks[i];
        if t.kind != Kind::Ident || toks[i + 1].text != "(" {
            continue;
        }
        if CALL_KEYWORDS.contains(&t.text.as_str()) {
            continue;
        }
        if i > 0 && toks[i - 1].text == "fn" {
            continue;
        }
        out.push((t.text.clone(), i));
    }
    out
}

/// Idents `V` for every `head :: V` path in the range: `(name, index of
/// the variant token)`.
pub fn path_variants(toks: &[Tok], rng: (usize, usize), head: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let end = rng.1.min(toks.len());
    if end < 4 {
        return out;
    }
    for i in rng.0..end - 3 {
        if toks[i].kind == Kind::Ident
            && toks[i].text == head
            && toks[i + 1].text == ":"
            && toks[i + 2].text == ":"
            && toks[i + 3].kind == Kind::Ident
        {
            out.push((toks[i + 3].text.clone(), i + 3));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> ParsedFile {
        ParsedFile::parse("test.rs", src)
    }

    #[test]
    fn fns_get_impl_qualified_names() {
        let f = parse(
            "impl Foo { fn a(&self) {} }\n\
             impl<T: Ord> Bar<T> for Baz { fn b() { let x = 1; } }\n\
             fn free() {}\n",
        );
        let q: Vec<&str> = f.fns.iter().map(|x| x.qname.as_str()).collect();
        assert_eq!(q, vec!["Foo::a", "Baz::b", "free"]);
    }

    #[test]
    fn where_clause_does_not_shadow_impl_type() {
        let f = parse("impl<T> Holder<T> where T: Clone { fn g(&self) {} }");
        assert_eq!(f.fns[0].qname, "Holder::g");
    }

    #[test]
    fn test_attrs_and_cfg_test_mods_are_flagged() {
        let f = parse(
            "fn real() {}\n\
             #[test]\nfn unit() {}\n\
             #[cfg(test)]\nmod tests {\n  fn helper() {}\n  impl Fix { fn h(&self) {} }\n}\n",
        );
        let flags: Vec<(String, bool)> =
            f.fns.iter().map(|x| (x.name.clone(), x.in_test)).collect();
        assert_eq!(
            flags,
            vec![
                ("real".into(), false),
                ("unit".into(), true),
                ("helper".into(), true),
                ("h".into(), true),
            ]
        );
    }

    #[test]
    fn attr_does_not_leak_past_non_fn_item() {
        let f = parse("#[cfg(test)]\nuse foo::bar;\nfn live() {}");
        assert!(!f.fns[0].in_test);
    }

    #[test]
    fn match_arms_patterns_and_bodies() {
        let f = parse(
            "fn d(w: Wire) { match w { Wire::A { x } => { one(x); }\n\
             Wire::B(..) | Wire::C => two(), _ => {} } }",
        );
        let fnb = f.fns[0].body;
        let mi = (fnb.0..fnb.1).find(|&i| f.toks[i].text == "match").unwrap();
        let arms = match_arms(&f.toks, mi, fnb.1);
        assert_eq!(arms.len(), 3);
        let pv: Vec<String> =
            path_variants(&f.toks, arms[1].pat, "Wire").into_iter().map(|(v, _)| v).collect();
        assert_eq!(pv, vec!["B", "C"]);
        let calls: Vec<String> =
            calls_in(&f.toks, arms[0].body).into_iter().map(|(c, _)| c).collect();
        assert_eq!(calls, vec!["one"]);
    }

    #[test]
    fn marker_same_line_and_contiguous_block_above() {
        let f = parse(
            "fn a() {\n\
             // lock-ok: reason spans\n\
             // two lines\n\
             x.lock();\n\
             y.lock();\n\
             }\n",
        );
        assert!(f.has_marker(4, "lock-ok"), "block directly above");
        assert!(!f.has_marker(5, "lock-ok"), "blank gap breaks the block");
        let g = parse("fn a() { x.lock(); } // lock-ok: same line");
        assert!(g.has_marker(1, "lock-ok"));
    }

    #[test]
    fn multiline_block_comment_marker_covers_all_lines() {
        let f = parse("fn a() {\n/* lock-ok:\n   long reason\n*/\nx.lock();\n}");
        assert!(f.has_marker(5, "lock-ok"));
    }

    #[test]
    fn calls_exclude_keywords_and_defs() {
        let f = parse("fn a() { if cond() { return helper(1); } match x() {} }");
        let calls: Vec<String> =
            calls_in(&f.toks, f.fns[0].body).into_iter().map(|(c, _)| c).collect();
        assert_eq!(calls, vec!["cond", "helper", "x"]);
    }
}
