//! Batch commit engine microbenchmark (EXPERIMENTS.md §Perf, L2/L1).
//!
//! Measures the XLA (AOT JAX/Pallas) `commit_batch` executable against
//! the native Rust path across batch sizes, plus the engine-service
//! round-trip cost the coordinator pays per flush. This locates the
//! break-even batch size for offloading the leader's commit computation.

#![cfg_attr(not(feature = "xla"), allow(dead_code, unused_imports))]

use std::time::Instant;
use wbam::runtime::{commit_batch_native, spawn_engine, BatchReq, CommitBatchEngine};
use wbam::types::{Gid, MsgId, Ts};
use wbam::util::Rng;

fn mk_batch(rng: &mut Rng, n: usize, groups: usize) -> (Vec<BatchReq>, Vec<Ts>) {
    let reqs = (0..n)
        .map(|i| BatchReq {
            m: MsgId::new(1, i as u32),
            lts: (0..groups).map(|g| Ts::new(rng.range(1, 1 << 30), Gid(g as u32))).collect(),
        })
        .collect();
    let pending = (0..64).map(|_| Ts::new(rng.range(1, 1 << 30), Gid(rng.below(10) as u32))).collect();
    (reqs, pending)
}

fn bench<F: FnMut()>(iters: u32, mut f: F) -> f64 {
    // warm-up
    for _ in 0..3 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!("batch_engine bench compares the XLA engine against the native path;");
    eprintln!("rebuild with `--features xla` (vendored PJRT bindings) to run it.");
}

#[cfg(feature = "xla")]
fn main() {
    let dir = wbam::runtime::engine::artifacts_dir();
    let eng = CommitBatchEngine::load(&dir).expect("run `make artifacts`");
    let svc = spawn_engine(dir).expect("engine service");
    let mut rng = Rng::new(0xBE);

    println!("== batch commit engine: XLA vs native (4 dest groups, 64 pending) ==\n");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>12} {:>12}",
        "batch", "native ns/op", "xla ns/op", "svc ns/op", "xla ns/msg", "native ns/msg"
    );
    for &b in &[1usize, 4, 8, 16, 32, 64, 128, 256] {
        let (reqs, pending) = mk_batch(&mut rng, b, 4);
        let native = bench(200, || {
            let out = commit_batch_native(&reqs, &pending);
            std::hint::black_box(out);
        });
        let xla = bench(100, || {
            let out = eng.commit_batch(&reqs, &pending).unwrap();
            std::hint::black_box(out);
        });
        let svc_t = bench(100, || {
            let out = svc.commit_batch(reqs.clone(), pending.clone()).unwrap();
            std::hint::black_box(out);
        });
        println!(
            "{:>6} {:>14.0} {:>14.0} {:>14.0} {:>12.0} {:>13.0}",
            b,
            native,
            xla,
            svc_t,
            xla / b as f64,
            native / b as f64
        );
    }
    svc.shutdown();
    println!("\n(see EXPERIMENTS.md §Perf for interpretation: the XLA path pays a fixed");
    println!(" PJRT dispatch cost amortised by batching; the native path is the default.)");
}
