//! Fig. 8 / Fig. 10 (extended): WAN latency–throughput curves.
//!
//! Paper setup: 10 groups replicated across 3 GCP data centres (Oregon,
//! N. Virginia, England; RTTs 60/75/130 ms), each group with one replica
//! per DC. The δ-dominated regime makes the message-delay counts of §V
//! directly visible: WbCast (3δ) < FastCast (4δ) < FT-Skeen (6δ); the
//! paper reports a ~2x average win over FastCast at 8000 clients.
//!
//! The trailing section sweeps the adaptive [`FlushPolicy`] on/off at
//! WAN delays (EXPERIMENTS.md §Coalescing knees, Fig. 8 rows): below
//! the CPU knee the δ-dominated latency hides the policy entirely; at
//! the knee the 200 µs window fattens frames and shifts it right.
//!
//! `cargo bench --bench fig8_wan` (WBAM_BENCH_FULL=1 for the full sweep).

use wbam::harness::{run, Net, Proto, RunCfg};
use wbam::sim::MS;
use wbam::types::FlushPolicy;

fn main() {
    let full = std::env::var("WBAM_BENCH_FULL").is_ok();
    let dests: &[usize] = if full { &[1, 2, 3, 4, 5, 6, 7, 8, 10] } else { &[1, 4, 7] };
    let clients: &[usize] = if full { &[500, 1000, 2000, 4000, 6000, 8000] } else { &[500, 2000, 8000] };

    println!("== Fig. 8{} — WAN (GCP 3-DC, 60/75/130 ms RTT), 10 groups ==", if full { "+10" } else { "" });
    for &d in dests {
        println!("\n-- {d} destination group(s) --");
        let mut last = Vec::new();
        for proto in Proto::EVAL {
            for &c in clients {
                let mut cfg = RunCfg::new(proto, 10, c, d, Net::Wan);
                cfg.duration = 3_000 * MS;
                cfg.warmup_frac = 0.3;
                cfg.seed = 8;
                let r = run(&cfg);
                println!("{}", r.row());
                if c == *clients.last().unwrap() {
                    last.push((proto, r.mean_lat_ms, r.throughput));
                }
            }
        }
        let wb = last.iter().find(|x| x.0 == Proto::WbCast).unwrap();
        let fc = last.iter().find(|x| x.0 == Proto::FastCast).unwrap();
        println!(
            ">> dest={d} @{} clients: WbCast vs FastCast — latency {:.2}x lower, throughput {:.2}x higher",
            clients.last().unwrap(),
            fc.1 / wb.1,
            wb.2 / fc.2
        );
    }

    // adaptive flush policy on/off at WAN delays (WbCast, dest=4): the
    // rows EXPERIMENTS.md §Coalescing knees records. Quiet-flush keeps
    // the sub-knee runs identical to immediate by construction; the
    // interesting delta is at the largest client counts.
    println!("\n== Fig. 8 adaptive-flush ablation (WbCast, 10 groups, dest=4) ==");
    let policies: [(&str, FlushPolicy); 2] = [
        ("immediate     ", FlushPolicy::immediate()),
        ("adaptive 200us", FlushPolicy { max_delay_us: 200, max_bytes: 1 << 20, flush_on_quiet: true }),
    ];
    for (name, policy) in policies {
        for &c in clients {
            let mut cfg = RunCfg::new(Proto::WbCast, 10, c, 4, Net::Wan);
            cfg.duration = 3_000 * MS;
            cfg.warmup_frac = 0.3;
            cfg.seed = 8;
            cfg.flush = policy;
            let r = run(&cfg);
            println!("flush={name} {}", r.row());
        }
    }
}
