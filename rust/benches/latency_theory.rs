//! §V latency theory (T-lat): measure collision-free and failure-free
//! latencies of all four protocols in the constant-δ, zero-CPU setting
//! and compare against Theorems 3–5 and the paper's table:
//!
//!   protocol   CFL   FFL          (paper)
//!   Skeen      2δ    4δ
//!   WbCast     3δ    5δ           ← the headline result
//!   FastCast   4δ    8δ
//!   FT-Skeen   6δ    12δ
//!
//! The collision-free number is a solo multicast (Theorem 3). The
//! failure-free number is found by an adversarial search over the Fig. 2
//! convoy scenario: group g1's clock is pumped by warm-up traffic so
//! that m's global timestamp is high; a conflicting m' is multicast at
//! offset `o` over a link that reaches g0's leader in ~0 (its other
//! paths take exactly δ); we report m's worst delivery latency over the
//! offset grid — Theorem 4 says it approaches C + CFL.
//!
//! Also regenerates the Fig. 5 message-flow count for WbCast.

use wbam::harness::{run, Net, Proto, RunCfg, ScriptedClient};
use wbam::invariants;
use wbam::protocols::fastcast::FastCastNode;
use wbam::protocols::ftskeen::FtSkeenNode;
use wbam::protocols::skeen::SkeenNode;
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::Node;
use wbam::sim::{delay::AdversarialDelay, CpuCost, SimConfig, World, MS};
use wbam::types::{Gid, GidSet, MsgId, Pid, Topology};

const D: u64 = MS; // δ = 1 ms

fn proto_nodes(proto: Proto, topo: &Topology) -> Vec<Box<dyn Node>> {
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            match proto {
                Proto::Skeen => nodes.push(Box::new(SkeenNode::new(p, topo.clone()))),
                Proto::FtSkeen => nodes.push(Box::new(FtSkeenNode::new(p, topo.clone()))),
                Proto::FastCast => nodes.push(Box::new(FastCastNode::new(p, topo.clone()))),
                Proto::WbCast => nodes.push(Box::new(WbNode::new(p, topo.clone(), WbConfig::default()))),
            }
        }
    }
    nodes
}

/// Measure m's delivery latency (max over groups of first delivery) in
/// the convoy scenario with the conflicting m' multicast at offset `o`.
fn convoy_latency(proto: Proto, o: u64) -> u64 {
    let f = if proto == Proto::Skeen { 0 } else { 1 };
    let topo = Topology::new(2, f);
    let leader_g0 = topo.initial_leader(Gid(0));
    let mut nodes = proto_nodes(proto, &topo);

    let warm_pid = topo.first_client_pid();
    let m_pid = Pid(warm_pid.0 + 1);
    let m2_pid = Pid(warm_pid.0 + 2);
    // warm-up: 10 single-group messages pump g1's clock (delivered long
    // before t0 = 100δ)
    let warm: Vec<(u64, GidSet)> = (0..10).map(|i| (i * D, GidSet::single(Gid(1)))).collect();
    let t0 = 100 * D;
    let both = GidSet::from_iter([Gid(0), Gid(1)]);
    nodes.push(Box::new(ScriptedClient::new(warm_pid, topo.clone(), warm)));
    nodes.push(Box::new(ScriptedClient::new(m_pid, topo.clone(), vec![(t0, both)])));
    nodes.push(Box::new(ScriptedClient::new(m2_pid, topo.clone(), vec![(t0 + o, both)])));

    // m' reaches g0's leader in ~0; every other link takes exactly δ
    let delay = AdversarialDelay::new(D).set(m2_pid, leader_g0, 1);
    let mut world = World::new(
        topo,
        nodes,
        SimConfig {
            delay: Box::new(delay),
            cpu: CpuCost::zero(),
            seed: 0,
            record_full: true,
            coalesce: true,
            flush: wbam::types::FlushPolicy::default(),
        },
    );
    world.run_to_quiescence(10_000_000);
    invariants::assert_safe(&world.trace);

    let m = MsgId::new(m_pid.0, 1);
    let first_in = |g: Gid| {
        world
            .trace
            .deliveries
            .iter()
            .filter(|d| d.m == m && world.trace.topo().group_of(d.pid) == Some(g))
            .map(|d| d.time)
            .min()
    };
    let g0 = first_in(Gid(0)).unwrap_or_else(|| panic!("{}: m not delivered in g0", proto.name()));
    let g1 = first_in(Gid(1)).unwrap_or_else(|| panic!("{}: m not delivered in g1", proto.name()));
    g0.max(g1) - t0
}

fn main() {
    println!("== T-lat: §V latency table (δ = 1 ms, constant delay, zero CPU) ==\n");
    println!(
        "{:<10} {:>8} {:>8}   {:>8} {:>8}   {}",
        "protocol", "CFL", "paper", "FFL", "paper", "(FFL = worst over convoy offsets, Thm. 4)"
    );

    let expect = [
        (Proto::Skeen, 2.0, 4.0),
        (Proto::WbCast, 3.0, 5.0),
        (Proto::FastCast, 4.0, 8.0),
        (Proto::FtSkeen, 6.0, 12.0),
    ];
    let mut ok = true;
    for (proto, cfl_paper, ffl_paper) in expect {
        // collision-free: solo multicast (Theorem 3)
        let mut cfg = RunCfg::new(proto, 2, 1, 2, Net::Theory { delta: D });
        cfg.max_requests = Some(1);
        let r = run(&cfg);
        let cfl = r.mean_lat_ms;

        // failure-free: adversarial offset search around the clock-update
        // latency C = FFL - CFL (Theorem 4)
        let c_delta = (ffl_paper - cfl_paper) as u64;
        let mut worst = 0u64;
        let mut at = 0u64;
        for step in 0..=(8 * c_delta) {
            let o = step * D / 8;
            let lat = convoy_latency(proto, o);
            if lat > worst {
                worst = lat;
                at = o;
            }
        }
        let ffl = worst as f64 / D as f64;
        let pass = (cfl - cfl_paper).abs() < 0.02 && (ffl_paper - ffl) < 0.2 && ffl <= ffl_paper + 0.02;
        ok &= pass;
        println!(
            "{:<10} {:>7.2}δ {:>7.0}δ   {:>7.2}δ {:>7.0}δ   worst offset {:.2}δ {}",
            proto.name(),
            cfl,
            cfl_paper,
            ffl,
            ffl_paper,
            at as f64 / D as f64,
            if pass { "✓" } else { "✗ MISMATCH" }
        );
    }

    // Fig. 5: WbCast collision-free message flow (2 groups, f = 1)
    let mut cfg = RunCfg::new(Proto::WbCast, 2, 1, 2, Net::Theory { delta: D });
    cfg.max_requests = Some(1);
    cfg.record_full = true;
    let mut world = wbam::harness::build_world(&cfg);
    world.run_to_quiescence(100_000);
    println!("\nFig. 5 flow (WbCast, 2 groups, solo message): {} protocol messages", world.trace.sends);

    println!("\n{}", if ok { "T-lat: all rows match the paper ✓" } else { "T-lat: MISMATCH ✗" });
    std::process::exit(if ok { 0 } else { 1 });
}
