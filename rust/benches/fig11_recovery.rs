//! Fig. 11: recovery timeline in the WAN deployment.
//!
//! Paper setup: 6000 client threads multicast to subsets of 4 of 10
//! groups; the leader of group 3 crashes. The paper reports ~6 s to
//! recover: ~2.5 s for the new leader to reach the LEADER state
//! (suspicion timeout + NEWLEADER/NEW_STATE exchange) and ~3.5 s to
//! clear the interrupted messages. We regenerate the throughput timeline
//! in the paper's 0.3 s bins and report the same phase breakdown.
//!
//! `cargo bench --bench fig11_recovery` (WBAM_BENCH_FULL=1: 6000 clients;
//! WBAM_SMOKE=1: a minutes-to-seconds CI mode — fewer clients, shorter
//! horizon, same crash → election → catch-up pipeline and the same
//! safety assertions, so the recovery path cannot bit-rot unnoticed)

use wbam::harness::{build_world, Net, Proto, RunCfg};
use wbam::invariants;
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::sim::MS;
use wbam::types::{Gid, Status};

fn main() {
    let full = std::env::var("WBAM_BENCH_FULL").is_ok();
    let smoke = std::env::var("WBAM_SMOKE").is_ok();
    let clients = if full {
        6000
    } else if smoke {
        300
    } else {
        1500
    };
    let crash_t = if smoke { 3_000 * MS } else { 6_000 * MS };
    let horizon = if smoke { 12_000 * MS } else { 20_000 * MS };
    let bin = 300 * MS;

    // failure detector sized like the paper's WAN deployment: the first
    // candidate suspects after ~2.4 s of leader silence
    let mut wb = WbConfig::with_failures(300 * MS);
    wb.hb_interval = 300 * MS;
    wb.hb_suspect_mult = 4; // rank-1 timeout = 0.3s * 4 * 2 = 2.4 s
    wb.retry_after = 1_500 * MS;
    wb.recovery_timeout = 8_000 * MS;

    let mut cfg = RunCfg::new(Proto::WbCast, 10, clients, 4, Net::Wan);
    cfg.wb = wb;
    cfg.resend_after = 2_000 * MS;
    cfg.record_full = true;
    cfg.seed = 11;

    println!(
        "== Fig. 11 — WAN recovery: leader of group 3 crashes at t = {} s ({clients} clients{}) ==\n",
        crash_t / MS / 1000,
        if smoke { ", smoke mode" } else { "" }
    );
    let mut world = build_world(&cfg);
    let victim = world.trace.topo().initial_leader(Gid(2)); // "group 3" (paper is 1-indexed)
    world.crash_at(victim, crash_t);
    world.run_until(horizon);

    // throughput timeline, 0.3 s bins (the paper's Fig. 11 resolution)
    let bins = world.trace.throughput_bins(bin, horizon);
    println!("aggregate throughput (multicasts/s), 0.3 s bins:");
    let peak = bins.iter().cloned().fold(1.0f64, f64::max);
    for (i, b) in bins.iter().enumerate() {
        let t = i as f64 * 0.3;
        let mark = if (t - 6.0).abs() < 0.15 { "  << crash" } else { "" };
        println!("  t={t:>5.1}s {b:>9.0}  {}{}", "#".repeat((b / peak * 56.0) as usize), mark);
    }

    // phase 1: time for the new leader to reach the LEADER state
    let new_leader = world
        .trace
        .topo()
        .members(Gid(2))
        .iter()
        .copied()
        .find(|&p| p != victim && world.node_as::<WbNode>(p).status() == Status::Leader);
    // phase 2: time for throughput to stabilise. NB: the post-recovery
    // steady state is *lower* than pre-crash — the new leader of group 3
    // lives in a different data centre, so requests touching it pay
    // cross-DC ACCEPT exchanges from then on (leader placement matters
    // in WANs). We therefore measure the outage against the new steady
    // state, and report the relocation penalty separately.
    let crash_bin = (crash_t / bin) as usize;
    let pre = bins[..crash_bin].iter().copied().sum::<f64>() / crash_bin as f64;
    let steady = bins[bins.len() - 10..].iter().copied().sum::<f64>() / 10.0;
    let recovered_bin = bins
        .iter()
        .enumerate()
        .skip(crash_bin + 1)
        .find(|(_, &b)| b >= 0.9 * steady)
        .map(|(i, _)| i)
        .unwrap_or(bins.len());

    println!("\nnew leader of group 3:        {:?}", new_leader.expect("no recovery"));
    if let Some(nl) = new_leader {
        let t = world.node_as::<WbNode>(nl).leader_since;
        println!("leader re-established after:  {:.1}s   (paper: ~2.5s)", (t - crash_t) as f64 / 1e9);
    }
    println!("pre-crash throughput:         {pre:>8.0}/s");
    println!("post-recovery steady state:   {steady:>8.0}/s  (lower: leader moved to another DC)");
    println!(
        "outage (to ≥90% of steady):   {:.1}s   (paper: ~6s = 2.5s election + 3.5s catch-up)",
        (recovered_bin - crash_bin) as f64 * 0.3
    );
    if let Some(nl) = new_leader {
        let n = world.node_as::<WbNode>(nl);
        println!(
            "new-leader stats:             recoveries {}→{}, retries {}",
            n.stats.recoveries_started, n.stats.recoveries_completed, n.stats.retries
        );
    }

    invariants::assert_safe(&world.trace);
    println!("\nsafety across the crash: OK");
}
