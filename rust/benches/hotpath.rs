//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf): the WbCast leader
//! commit path and the simulator event loop, plus an ablation of the
//! ordered-delivery data structure (the naive Fig. 4 line-21 scan vs the
//! frontier BTreeSet index).

use std::time::Instant;
use wbam::harness::{run, Net, Proto, RunCfg};
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::Node;
use wbam::sim::MS;
use wbam::types::{Ballot, Gid, GidSet, MsgId, MsgMeta, Pid, Topology, Ts, Wire};

/// Drive one leader through the full ACCEPT/ACK/commit cycle in memory
/// (no network, no sim): the pure protocol-code cost per multicast.
fn leader_commit_path(n: u32) -> f64 {
    let topo = Topology::new(2, 1);
    let mut leader = WbNode::new(Pid(0), topo.clone(), WbConfig::default());
    let b0 = Ballot::new(1, Pid(0));
    let b1 = Ballot::new(1, Pid(3));
    let dest = GidSet::from_iter([Gid(0), Gid(1)]);
    let t0 = Instant::now();
    for i in 1..=n {
        let m = MsgId::new(9, i);
        let meta = MsgMeta::new(m, dest, vec![0u8; 20]);
        // client MULTICAST
        let out = leader.on_wire(Pid(9), Wire::Multicast { meta: meta.clone() }, 0);
        std::hint::black_box(&out);
        // own ACCEPT (self), remote leader's ACCEPT
        let lts0 = Ts::new(i as u64, Gid(0));
        let lts1 = Ts::new(i as u64, Gid(1));
        leader.on_wire(Pid(0), Wire::Accept { meta: meta.clone(), g: Gid(0), bal: b0, lts: lts0 }, 0);
        leader.on_wire(Pid(3), Wire::Accept { meta, g: Gid(1), bal: b1, lts: lts1 }, 0);
        // quorum of ACCEPT_ACKs from both groups
        let bals = vec![(Gid(0), b0), (Gid(1), b1)];
        for p in [Pid(0), Pid(1), Pid(3), Pid(4)] {
            let g = topo.group_of(p).unwrap();
            let out = leader.on_wire(p, Wire::AcceptAck { m, g, bals: bals.clone() }, 0);
            std::hint::black_box(&out);
        }
        assert_eq!(leader.stats.committed, i as u64);
    }
    t0.elapsed().as_nanos() as f64 / n as f64
}

fn main() {
    println!("== L3 hot path ==\n");

    let per_commit = leader_commit_path(50_000);
    println!("leader commit path (in-memory, 2 groups): {per_commit:.0} ns/multicast");

    // simulator event throughput under load
    let t0 = Instant::now();
    let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
    cfg.duration = 300 * MS;
    let r = run(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    let events = r.completed as f64 * r.msgs_per_multicast;
    println!(
        "saturated LAN sim (10 groups, 800 clients): {:.0} virtual msgs in {wall:.2}s wall = {:.2} M events/s",
        events,
        events / wall / 1e6
    );
    println!("  {}", r.row());

    // throughput sensitivity to the commit-batch size (the XLA engine's
    // amortisation knob) on the simulated cluster
    println!("\ncommit staging ablation (sim, batch_threshold sweep):");
    for &bt in &[1usize, 4, 16] {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = 300 * MS;
        cfg.wb = WbConfig { batch_threshold: bt, batch_flush_after: 200_000, ..WbConfig::default() };
        let r = run(&cfg);
        println!("  batch_threshold={bt:<3} {}", r.row());
    }

    // ablation: replication degree f (group size 2f+1). WbCast's quorum
    // round trip scales with group size; latency is unchanged (still 3δ
    // message depth), throughput pays the extra fan-out.
    println!("\nreplication-degree ablation (WbCast, LAN, 400 clients, dest=3):");
    for &f in &[1usize, 2, 3] {
        let mut cfg = RunCfg::new(Proto::WbCast, 6, 400, 3, Net::Lan);
        cfg.f = f;
        cfg.duration = 300 * MS;
        let r = run(&cfg);
        println!("  f={f} (groups of {}): {}", 2 * f + 1, r.row());
    }

    // ablation: payload size (the paper uses 20-byte messages; the CPU
    // model charges per byte, so this shows the payload-insensitivity of
    // the protocol itself)
    println!("\npayload-size ablation (WbCast, LAN, 400 clients, dest=3):");
    for &sz in &[20usize, 200, 2000] {
        let mut cfg = RunCfg::new(Proto::WbCast, 6, 400, 3, Net::Lan);
        cfg.duration = 300 * MS;
        let mut w = wbam::harness::build_world(&cfg);
        let _ = &mut w; // payload knob lives on ClientCfg; reuse run() via cfg when available
        drop(w);
        // run() uses default 20B; emulate larger payloads via a custom world
        let r = run_payload(&cfg, sz);
        println!("  payload={sz:<5} {}", r.row());
    }
}

/// run() with an overridden client payload size.
fn run_payload(cfg: &RunCfg, payload: usize) -> wbam::harness::RunResult {
    use wbam::client::{Client, ClientCfg};
    use wbam::protocols::wbcast::WbNode;
    use wbam::sim::{CpuCost, LanDelay, SimConfig, World};
    use wbam::types::{Pid, Topology};
    let topo = Topology::new(cfg.groups, cfg.f);
    let mut nodes: Vec<Box<dyn wbam::protocols::Node>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            nodes.push(Box::new(WbNode::new(p, topo.clone(), cfg.wb)));
        }
    }
    for c in 0..cfg.clients {
        let pid = Pid(topo.first_client_pid().0 + c as u32);
        let ccfg = ClientCfg { dest_groups: cfg.dest_groups, payload, ..Default::default() };
        nodes.push(Box::new(Client::new(pid, topo.clone(), ccfg, cfg.seed ^ (c as u64 + 1))));
    }
    let mut w = World::new(
        topo,
        nodes,
        SimConfig { delay: Box::new(LanDelay::cloudlab()), cpu: CpuCost::lan_server(), seed: cfg.seed, record_full: false },
    );
    w.run_until(cfg.duration);
    wbam::harness::summarize(cfg, &w.trace, (cfg.duration as f64 * cfg.warmup_frac) as u64, cfg.duration)
}
