//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf): the WbCast leader
//! commit path driven through the reusable [`Outbox`] (zero per-event
//! effect allocations), the simulator event loop, and the headline
//! ablation of this refactor — destination-coalesced wire batching
//! (`Wire::Batch`) on vs off at saturation.

use std::time::Instant;
use wbam::client::{Client, ClientCfg};
use wbam::coordinator::Cluster;
use wbam::harness::{run, Net, Proto, RunCfg};
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::{Node, Outbox};
use wbam::sim::MS;
use wbam::types::{Ballot, Gid, GidSet, MsgId, MsgMeta, Pid, ShardMap, Topology, Ts, Wire};

/// Drive one leader through the full ACCEPT/ACK/commit cycle in memory
/// (no network, no sim): the pure protocol-code cost per multicast. The
/// single outbox is reused across all events — the steady state does no
/// effect-vector allocation.
fn leader_commit_path(n: u32) -> f64 {
    let topo = Topology::new(2, 1);
    let mut leader = WbNode::new(Pid(0), topo.clone(), WbConfig::default());
    let b0 = Ballot::new(1, Pid(0));
    let b1 = Ballot::new(1, Pid(3));
    let dest = GidSet::from_iter([Gid(0), Gid(1)]);
    let mut out = Outbox::new();
    let t0 = Instant::now();
    for i in 1..=n {
        let m = MsgId::new(9, i);
        let meta = MsgMeta::new(m, dest, vec![0u8; 20]);
        // client MULTICAST
        leader.on_wire(Pid(9), Wire::Multicast { meta: meta.clone() }, 0, &mut out);
        std::hint::black_box(out.sends());
        out.clear();
        // own ACCEPT (self), remote leader's ACCEPT
        let lts0 = Ts::new(i as u64, Gid(0));
        let lts1 = Ts::new(i as u64, Gid(1));
        leader.on_wire(Pid(0), Wire::Accept { meta: meta.clone(), g: Gid(0), bal: b0, lts: lts0 }, 0, &mut out);
        out.clear();
        leader.on_wire(Pid(3), Wire::Accept { meta, g: Gid(1), bal: b1, lts: lts1 }, 0, &mut out);
        out.clear();
        // quorum of ACCEPT_ACKs from both groups
        let bals = vec![(Gid(0), b0), (Gid(1), b1)];
        for p in [Pid(0), Pid(1), Pid(3), Pid(4)] {
            let g = topo.group_of(p).unwrap();
            leader.on_wire(p, Wire::AcceptAck { m, g, bals: bals.clone() }, 0, &mut out);
            std::hint::black_box(out.sends());
            out.clear();
        }
        assert_eq!(leader.stats.committed, i as u64);
    }
    t0.elapsed().as_nanos() as f64 / n as f64
}

fn main() {
    println!("== L3 hot path ==\n");

    let per_commit = leader_commit_path(50_000);
    println!("leader commit path (in-memory, 2 groups, reused outbox): {per_commit:.0} ns/multicast");

    // simulator event throughput under load
    let t0 = Instant::now();
    let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
    cfg.duration = 300 * MS;
    let r = run(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    let events = r.completed as f64 * r.msgs_per_multicast;
    println!(
        "saturated LAN sim (10 groups, 800 clients): {:.0} virtual msgs in {wall:.2}s wall = {:.2} M events/s",
        events,
        events / wall / 1e6
    );
    println!("  {}", r.row());

    // headline ablation: destination-coalesced wire batching on vs off at
    // saturation. Frames amortise the per-message recv/send CPU charges
    // (and, on real transports, the per-message encode + syscall), which
    // is where the knee of the throughput curve comes from. Acceptance
    // bar for the refactor: ≥20% more completed multicasts with
    // coalescing on.
    println!("\nwire-batching ablation (sim, 10 groups, 800 clients, dest=4, commit batch 16):");
    let mut thru = [0f64; 2];
    for (i, &co) in [false, true].iter().enumerate() {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = 300 * MS;
        cfg.coalesce = co;
        cfg.wb = WbConfig { batch_threshold: 16, batch_flush_after: 200_000, ..WbConfig::default() };
        let r = run(&cfg);
        thru[i] = r.throughput;
        println!("  coalesce={:<5} {}", co, r.row());
    }
    let gain = (thru[1] / thru[0] - 1.0) * 100.0;
    println!(
        "  => coalescing throughput gain at saturation: {gain:+.1}% {}",
        if gain >= 20.0 { "(≥20% target met)" } else { "(below 20% target)" }
    );

    // leader sharding: S independent protocol instances behind each
    // endpoint, clients partitioned by client id. Every shard is its own
    // single-threaded server in the sim's CPU model, so the saturation
    // knee lifts with the shard count. Acceptance bar: ≥1.5x completed
    // multicasts at saturation with 4 shards.
    println!("\nleader-sharding ablation (sim, 2 groups, 256 clients, dest=2, saturation):");
    let mut sharded = [0f64; 2];
    for (i, &s) in [1usize, 4].iter().enumerate() {
        let mut cfg = RunCfg::new(Proto::WbCast, 2, 256, 2, Net::Lan);
        cfg.duration = 300 * MS;
        cfg.shards = s;
        let r = run(&cfg);
        sharded[i] = r.throughput;
        println!("  shards={s:<2} {}", r.row());
    }
    let gain = sharded[1] / sharded[0];
    println!(
        "  => 1-shard vs 4-shard saturation throughput: {gain:.2}x {}",
        if gain >= 1.5 { "(≥1.5x target met)" } else { "(below 1.5x target)" }
    );

    // the same comparison on the real threaded ShardedRuntime over the
    // in-process mesh: one worker thread per shard behind each endpoint,
    // so the actual speedup is bounded by the host's core count
    println!("\nsharded runtime (real threads, 2 groups x 3 replicas, 64 clients, dest=2, 3s):");
    for &s in &[1usize, 4] {
        let thru = real_cluster_throughput(s, 64, 3);
        println!("  shards={s:<2} {thru:.0} multicasts/s");
    }

    // throughput sensitivity to the commit-batch size (the XLA engine's
    // amortisation knob) on the simulated cluster
    println!("\ncommit staging ablation (sim, batch_threshold sweep):");
    for &bt in &[1usize, 4, 16] {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = 300 * MS;
        cfg.wb = WbConfig { batch_threshold: bt, batch_flush_after: 200_000, ..WbConfig::default() };
        let r = run(&cfg);
        println!("  batch_threshold={bt:<3} {}", r.row());
    }

    // ablation: replication degree f (group size 2f+1). WbCast's quorum
    // round trip scales with group size; latency is unchanged (still 3δ
    // message depth), throughput pays the extra fan-out.
    println!("\nreplication-degree ablation (WbCast, LAN, 400 clients, dest=3):");
    for &f in &[1usize, 2, 3] {
        let mut cfg = RunCfg::new(Proto::WbCast, 6, 400, 3, Net::Lan);
        cfg.f = f;
        cfg.duration = 300 * MS;
        let r = run(&cfg);
        println!("  f={f} (groups of {}): {}", 2 * f + 1, r.row());
    }

    // ablation: payload size (the paper uses 20-byte messages; the CPU
    // model charges per byte, so this shows the payload-insensitivity of
    // the protocol itself)
    println!("\npayload-size ablation (WbCast, LAN, 400 clients, dest=3):");
    for &sz in &[20usize, 200, 2000] {
        let mut cfg = RunCfg::new(Proto::WbCast, 6, 400, 3, Net::Lan);
        cfg.duration = 300 * MS;
        let r = run_payload(&cfg, sz);
        println!("  payload={sz:<5} {}", r.row());
    }
}

/// Closed-loop saturation throughput of the real threaded
/// [`wbam::coordinator::ShardedRuntime`]: `shards` WbCast instances
/// behind each of the 6 member endpoints, clients on their own
/// endpoints, measured over `secs` of wall clock.
fn real_cluster_throughput(shards: usize, n_clients: u32, secs: u64) -> f64 {
    let map = ShardMap::new(2, 1, shards);
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    let mut hosts: Vec<Vec<Box<dyn Node>>> = Vec::new();
    for e in map.endpoints() {
        let mut ns: Vec<Box<dyn Node>> = Vec::new();
        for p in map.hosted_by(e) {
            let s = map.shard_of(p).expect("member pid");
            ns.push(Box::new(WbNode::new(p, map.topo(s), wb)));
        }
        hosts.push(ns);
    }
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        let s = map.client_shard(pid);
        let cfg = ClientCfg { dest_groups: 2, resend_after: 2_000_000_000, ..Default::default() };
        hosts.push(vec![Box::new(Client::new(pid, map.topo(s), cfg, 0xBE5C + c as u64))]);
    }
    let t0 = Instant::now();
    let cluster = Cluster::launch_hosts(hosts, None);
    std::thread::sleep(std::time::Duration::from_secs(secs));
    let nodes = cluster.shutdown();
    let wall = t0.elapsed().as_secs_f64();
    let mut completed = 0usize;
    for n in &nodes {
        let any: &dyn Node = &**n;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    completed as f64 / wall
}

/// run() with an overridden client payload size.
fn run_payload(cfg: &RunCfg, payload: usize) -> wbam::harness::RunResult {
    use wbam::sim::{CpuCost, LanDelay, SimConfig, World};
    let topo = Topology::new(cfg.groups, cfg.f);
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            nodes.push(Box::new(WbNode::new(p, topo.clone(), cfg.wb)));
        }
    }
    for c in 0..cfg.clients {
        let pid = Pid(topo.first_client_pid().0 + c as u32);
        let ccfg = ClientCfg { dest_groups: cfg.dest_groups, payload, ..Default::default() };
        nodes.push(Box::new(Client::new(pid, topo.clone(), ccfg, cfg.seed ^ (c as u64 + 1))));
    }
    let mut w = World::new(
        topo,
        nodes,
        SimConfig {
            delay: Box::new(LanDelay::cloudlab()),
            cpu: CpuCost::lan_server(),
            seed: cfg.seed,
            record_full: false,
            coalesce: cfg.coalesce,
        },
    );
    w.run_until(cfg.duration);
    wbam::harness::summarize(cfg, &w.trace, (cfg.duration as f64 * cfg.warmup_frac) as u64, cfg.duration)
}
