//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf): the WbCast leader
//! commit path driven through the reusable [`Outbox`] (zero per-event
//! effect allocations), the simulator event loop, the headline wire
//! batching / sharding ablations at saturation, the inline-vs-threaded
//! 1-shard runtime latency comparison, the adaptive flush-policy
//! ablation, the zero-copy decode allocation ablation, and the
//! **three-way tcp / epoll / io_uring transport ablation** over real
//! localhost sockets (EXPERIMENTS.md §Three-way transport ablation):
//! throughput, p50/p99 round trip, threads, syscalls- and
//! allocations-per-multicast for each transport at the Fig. 7
//! operating point.
//!
//! Besides the human table on stdout, the run writes every row to
//! `BENCH_hotpath.json` (in the bench's working directory) so the perf
//! trajectory is machine-trackable across PRs.
//!
//! Set `WBAM_SMOKE=1` for a seconds-long bit-rot check (tiny iteration
//! counts; the printed numbers are meaningless) — CI runs this mode.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;
use wbam::client::{Client, ClientCfg};
use wbam::coordinator::{one_shard_round_trip_ns, Cluster, ShardedRuntime};
use wbam::harness::{run, Net, Proto, RunCfg};
use wbam::net::{syscalls_observed, InProcMesh, TcpTransport, Transport};
use wbam::obs::{CoreMetrics, Registry};
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::{Node, Outbox};
use wbam::sim::MS;
use wbam::types::{Ballot, FlushPolicy, Gid, GidSet, MsgId, MsgMeta, Pid, ShardMap, Topology, Ts, Wire};

/// Counting wrapper over the system allocator: the per-message
/// allocation gauge the zero-copy acceptance bar is measured with.
/// Frees are not counted — the gauge is allocation pressure, not live
/// bytes.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

// SAFETY: defers to the system allocator; the counters are relaxed
// atomics with no allocation of their own.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Machine-readable mirror of the printed tables, one flat row per
/// configuration; serialized by hand (no serde in the dependency
/// budget) into `BENCH_hotpath.json`.
#[derive(Default)]
struct JsonRows(Vec<String>);

impl JsonRows {
    fn push(&mut self, section: &str, config: &str, metrics: &[(&str, f64)]) {
        let mut s = format!("    {{\"section\": \"{section}\", \"config\": \"{config}\"");
        for (k, v) in metrics {
            if v.is_finite() {
                s.push_str(&format!(", \"{k}\": {v}"));
            } else {
                s.push_str(&format!(", \"{k}\": null"));
            }
        }
        s.push('}');
        self.0.push(s);
    }

    fn write(&self, smoke: bool) {
        let body = self.0.join(",\n");
        let out = format!(
            "{{\n  \"bench\": \"hotpath\",\n  \"smoke\": {smoke},\n  \"rows\": [\n{body}\n  ]\n}}\n"
        );
        match std::fs::write("BENCH_hotpath.json", &out) {
            Ok(()) => println!("\nwrote BENCH_hotpath.json ({} rows)", self.0.len()),
            Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
        }
    }
}

/// One measured transport-ablation configuration.
struct AblationRow {
    kind: &'static str,
    throughput: f64,
    p50_ms: f64,
    p99_ms: f64,
    threads: usize,
    allocs_per_mc: f64,
    alloc_kb_per_mc: f64,
    syscalls_per_mc: f64,
}

/// Drive one leader through the full ACCEPT/ACK/commit cycle in memory
/// (no network, no sim): the pure protocol-code cost per multicast. The
/// single outbox is reused across all events — the steady state does no
/// effect-vector allocation.
fn leader_commit_path(n: u32) -> f64 {
    let topo = Topology::new(2, 1);
    let mut leader = WbNode::new(Pid(0), topo.clone(), WbConfig::default());
    let b0 = Ballot::new(1, Pid(0));
    let b1 = Ballot::new(1, Pid(3));
    let dest = GidSet::from_iter([Gid(0), Gid(1)]);
    let mut out = Outbox::new();
    let t0 = Instant::now();
    for i in 1..=n {
        let m = MsgId::new(9, i);
        let meta = MsgMeta::new(m, dest, vec![0u8; 20]);
        // client MULTICAST
        leader.on_wire(Pid(9), Wire::Multicast { meta: meta.clone() }, 0, &mut out);
        std::hint::black_box(out.sends());
        out.clear();
        // own ACCEPT (self), remote leader's ACCEPT
        let lts0 = Ts::new(i as u64, Gid(0));
        let lts1 = Ts::new(i as u64, Gid(1));
        leader.on_wire(Pid(0), Wire::Accept { meta: meta.clone(), g: Gid(0), bal: b0, lts: lts0 }, 0, &mut out);
        out.clear();
        leader.on_wire(Pid(3), Wire::Accept { meta, g: Gid(1), bal: b1, lts: lts1 }, 0, &mut out);
        out.clear();
        // quorum of ACCEPT_ACKs from both groups
        let bals = vec![(Gid(0), b0), (Gid(1), b1)];
        for p in [Pid(0), Pid(1), Pid(3), Pid(4)] {
            let g = topo.group_of(p).unwrap();
            leader.on_wire(p, Wire::AcceptAck { m, g, bals: bals.clone() }, 0, &mut out);
            std::hint::black_box(out.sends());
            out.clear();
        }
        assert_eq!(leader.stats.committed, i as u64);
    }
    t0.elapsed().as_nanos() as f64 / n as f64
}

fn main() {
    println!("== L3 hot path ==\n");

    // WBAM_SMOKE=1: tiny iteration counts so CI can catch bench bit-rot
    // in seconds (the numbers are not meaningful in this mode)
    let smoke = std::env::var("WBAM_SMOKE").is_ok();
    if smoke {
        println!("(smoke mode: tiny iteration counts, numbers are meaningless)\n");
    }
    let commit_iters = if smoke { 2_000 } else { 50_000 };
    let dur = if smoke { 30 * MS } else { 300 * MS };
    let secs = if smoke { 1 } else { 3 };
    let trips = if smoke { 300 } else { 5_000 };
    let mut json = JsonRows::default();

    let per_commit = leader_commit_path(commit_iters);
    println!("leader commit path (in-memory, 2 groups, reused outbox): {per_commit:.0} ns/multicast");
    json.push("leader_commit", "2groups_reused_outbox", &[("ns_per_multicast", per_commit)]);

    // simulator event throughput under load
    let t0 = Instant::now();
    let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
    cfg.duration = dur;
    let r = run(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    let events = r.completed as f64 * r.msgs_per_multicast;
    println!(
        "saturated LAN sim (10 groups, 800 clients): {:.0} virtual msgs in {wall:.2}s wall = {:.2} M events/s",
        events,
        events / wall / 1e6
    );
    println!("  {}", r.row());

    // headline ablation: destination-coalesced wire batching on vs off at
    // saturation. Frames amortise the per-message recv/send CPU charges
    // (and, on real transports, the per-message encode + syscall), which
    // is where the knee of the throughput curve comes from. Acceptance
    // bar for the refactor: ≥20% more completed multicasts with
    // coalescing on.
    println!("\nwire-batching ablation (sim, 10 groups, 800 clients, dest=4, commit batch 16):");
    let mut thru = [0f64; 2];
    for (i, &co) in [false, true].iter().enumerate() {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = dur;
        cfg.coalesce = co;
        cfg.wb = WbConfig { batch_threshold: 16, batch_flush_after: 200_000, ..WbConfig::default() };
        let r = run(&cfg);
        thru[i] = r.throughput;
        println!("  coalesce={:<5} {}", co, r.row());
        json.push("wire_batching", &format!("coalesce={co}"), &[("throughput", r.throughput)]);
    }
    let gain = (thru[1] / thru[0] - 1.0) * 100.0;
    println!(
        "  => coalescing throughput gain at saturation: {gain:+.1}% {}",
        if gain >= 20.0 { "(≥20% target met)" } else { "(below 20% target)" }
    );

    // adaptive per-link coalescing at the same saturated operating
    // point: holding a link for up to 200 µs under load folds wires from
    // *several* events into one frame (flush-per-cycle only merges one
    // event's fan-out), trading bounded extra latency for a higher CPU
    // knee. See EXPERIMENTS.md §Coalescing knees.
    println!("\nadaptive flush-policy ablation (sim, 10 groups, 800 clients, dest=4):");
    let policies: [(&str, FlushPolicy); 3] = [
        ("immediate        ", FlushPolicy::immediate()),
        ("adaptive 200us   ", FlushPolicy { max_delay_us: 200, max_bytes: 1 << 20, flush_on_quiet: true }),
        ("adaptive no-quiet", FlushPolicy { max_delay_us: 200, max_bytes: 1 << 20, flush_on_quiet: false }),
    ];
    let mut athru = [0f64; 3];
    for (i, (name, p)) in policies.iter().enumerate() {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = dur;
        cfg.flush = *p;
        let r = run(&cfg);
        athru[i] = r.throughput;
        println!("  {name} {}", r.row());
        json.push("flush_policy", name.trim(), &[("throughput", r.throughput)]);
    }
    println!(
        "  => adaptive (quiet) vs immediate at saturation: {:+.1}%; strict window: {:+.1}%",
        (athru[1] / athru[0] - 1.0) * 100.0,
        (athru[2] / athru[0] - 1.0) * 100.0
    );

    // leader sharding: S independent protocol instances behind each
    // endpoint, clients partitioned by client id. Every shard is its own
    // single-threaded server in the sim's CPU model, so the saturation
    // knee lifts with the shard count. Acceptance bar: ≥1.5x completed
    // multicasts at saturation with 4 shards.
    println!("\nleader-sharding ablation (sim, 2 groups, 256 clients, dest=2, saturation):");
    let mut sharded = [0f64; 2];
    for (i, &s) in [1usize, 4].iter().enumerate() {
        let mut cfg = RunCfg::new(Proto::WbCast, 2, 256, 2, Net::Lan);
        cfg.duration = dur;
        cfg.shards = s;
        let r = run(&cfg);
        sharded[i] = r.throughput;
        println!("  shards={s:<2} {}", r.row());
        json.push("leader_sharding_sim", &format!("shards={s}"), &[("throughput", r.throughput)]);
    }
    let gain = sharded[1] / sharded[0];
    println!(
        "  => 1-shard vs 4-shard saturation throughput: {gain:.2}x {}",
        if gain >= 1.5 { "(≥1.5x target met)" } else { "(below 1.5x target)" }
    );

    // the same comparison on the real threaded ShardedRuntime over the
    // in-process mesh: one worker thread per shard behind each endpoint,
    // so the actual speedup is bounded by the host's core count
    println!("\nsharded runtime (real threads, 2 groups x 3 replicas, 64 clients, dest=2, {secs}s):");
    for &s in &[1usize, 4] {
        let thru = real_cluster_throughput(s, 64, secs, None);
        println!("  shards={s:<2} {thru:.0} multicasts/s");
        json.push("sharded_runtime_mesh", &format!("shards={s}"), &[("throughput", thru)]);
    }

    // metrics-overhead ablation (EXPERIMENTS.md §Metrics overhead): the
    // same 1-shard mesh deployment with the full live-observability
    // pack attached (per-path counters, e2e + stage histograms, HLL
    // client estimator, flight recorder) and wall-clock client stamping
    // vs the bare runtime. Acceptance bar: metrics-on throughput within
    // 3% of metrics-off.
    println!("\nmetrics-overhead ablation (real threads, 2 groups x 3 replicas, 64 clients, dest=2, {secs}s):");
    let off = real_cluster_throughput(1, 64, secs, None);
    let reg = Registry::new();
    let cm = CoreMetrics::register(&reg);
    let on = real_cluster_throughput(1, 64, secs, Some(Arc::clone(&cm)));
    let overhead = (1.0 - on / off) * 100.0;
    println!("  metrics=off {off:.0} multicasts/s");
    println!(
        "  metrics=on  {on:.0} multicasts/s ({} deliveries recorded, {} flight events)",
        cm.delivered_total(),
        cm.flight.pushed()
    );
    println!(
        "  => instrumentation overhead: {overhead:+.1}% {}",
        if overhead <= 3.0 { "(within 3% target)" } else { "(ABOVE 3% target)" }
    );
    json.push("metrics_overhead", "off", &[("throughput", off)]);
    json.push("metrics_overhead", "on", &[("throughput", on), ("overhead_pct", overhead)]);

    // three-way transport ablation (EXPERIMENTS.md §Three-way transport
    // ablation): the same closed-loop deployment over real localhost
    // sockets on the thread-per-connection TCP transport, the epoll
    // event loop and the io_uring completion loop. Threads make the
    // O(connections)-vs-O(1) cost visible; syscalls/multicast make the
    // readiness-vs-completion batching visible (io_uring submits and
    // reaps a burst in one enter); allocations/multicast is the
    // zero-copy payload-path gauge. io_uring self-skips (with the probe
    // reason) where the kernel or sandbox cannot run it. Acceptance
    // bars: epoll >= 1x tcp, io_uring >= 1x epoll at the saturation
    // knee.
    let tcli = if smoke { 8 } else { 32 };
    println!("\ntransport ablation (real sockets, 2 groups x 3 replicas, {tcli} clients, dest=2, {secs}s):");
    println!(
        "  {:<7}{:>12}  {:>9}{:>9}{:>9}{:>12}{:>12}{:>11}",
        "", "multicasts/s", "p50 ms", "p99 ms", "threads", "allocs/mc", "allocKB/mc", "syscall/mc"
    );
    let mut rows: Vec<AblationRow> = Vec::new();
    for (i, &kind) in ["tcp", "epoll", "uring"].iter().enumerate() {
        if kind != "tcp" && !cfg!(target_os = "linux") {
            println!("  {kind:<6} (skipped: requires linux)");
            continue;
        }
        #[cfg(target_os = "linux")]
        let skip_reason = if kind == "uring" { wbam::net::uring_probe().err() } else { None };
        #[cfg(not(target_os = "linux"))]
        let skip_reason: Option<String> = None;
        if let Some(reason) = skip_reason {
            println!("  uring  (skipped: {reason})");
            continue;
        }
        // process-keyed bases (like the unit tests' next_port) so a
        // concurrent or back-to-back run cannot collide on a listener
        let base = 33000 + (std::process::id() % 300) as u16 * 96 + (i as u16) * 48;
        let r = socket_cluster_run(kind, tcli, secs, base);
        println!(
            "  {:<7}{:>12.0}  {:>9.3}{:>9.3}{:>9}{:>12.1}{:>12.2}{:>11.2}",
            r.kind, r.throughput, r.p50_ms, r.p99_ms, r.threads, r.allocs_per_mc, r.alloc_kb_per_mc, r.syscalls_per_mc
        );
        json.push(
            "transport_ablation",
            r.kind,
            &[
                ("throughput", r.throughput),
                ("p50_ms", r.p50_ms),
                ("p99_ms", r.p99_ms),
                ("threads", r.threads as f64),
                ("allocs_per_multicast", r.allocs_per_mc),
                ("alloc_kb_per_multicast", r.alloc_kb_per_mc),
                ("syscalls_per_multicast", r.syscalls_per_mc),
            ],
        );
        rows.push(r);
    }
    let find = |k: &str| rows.iter().find(|r| r.kind == k);
    if let (Some(t), Some(e)) = (find("tcp"), find("epoll")) {
        let gain = e.throughput / t.throughput;
        println!(
            "  => epoll vs thread-per-conn throughput: {gain:.2}x {}",
            if gain >= 1.0 { "(≥1x target met)" } else { "(below 1x target)" }
        );
    }
    if let (Some(e), Some(u)) = (find("epoll"), find("uring")) {
        let gain = u.throughput / e.throughput;
        println!(
            "  => io_uring vs epoll throughput: {gain:.2}x {}",
            if gain >= 1.0 { "(≥1x target met)" } else { "(below 1x target)" }
        );
    }

    // zero-copy decode ablation: the same encoded 64-message batch
    // frame decoded with the copying `codec::decode` (every payload a
    // fresh Vec — the pre-zero-copy behaviour) vs `decode_shared`
    // (payloads are refcounted views into one Arc frame). The delta is
    // the per-frame allocation saving every transport's receive path
    // now gets.
    println!("\nzero-copy decode ablation (64-message batch, 200 B payloads):");
    let batch = Wire::Batch(
        (0..64u32)
            .map(|i| Wire::Multicast {
                meta: MsgMeta::new(MsgId::new(9, i), GidSet::single(Gid(0)), vec![i as u8; 200]),
            })
            .collect(),
    );
    let bytes = wbam::codec::encode(&batch);
    let frame: Arc<[u8]> = bytes.clone().into();
    let dec_iters = if smoke { 200u64 } else { 20_000 };
    let a0 = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..dec_iters {
        std::hint::black_box(wbam::codec::decode(&bytes).expect("decode"));
    }
    let per_copy = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / dec_iters as f64;
    let a0 = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..dec_iters {
        std::hint::black_box(wbam::codec::decode_shared(&frame, 0, frame.len()).expect("decode_shared"));
    }
    let per_shared = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / dec_iters as f64;
    let saved = (1.0 - per_shared / per_copy) * 100.0;
    println!("  copying decode: {per_copy:.1} allocs/frame");
    println!("  shared decode:  {per_shared:.1} allocs/frame");
    println!(
        "  => zero-copy allocation saving: {saved:.1}% {}",
        if per_shared < per_copy { "(reduction confirmed)" } else { "(NO reduction)" }
    );
    json.push(
        "zero_copy_decode",
        "batch64_200B",
        &[("copying_allocs_per_frame", per_copy), ("shared_allocs_per_frame", per_shared), ("saving_pct", saved)],
    );

    // inline 1-shard fast path vs the threaded worker/flusher pipeline
    // on single-message latency: the inline loop removes two channel
    // hops and two thread wakeups per message. Acceptance bar: >= 20%
    // lower round-trip latency, pinned (via the same shared harness) as
    // coordinator::tests::inline_single_shard_beats_threaded_on_latency.
    println!("\n1-shard runtime ping-pong ({trips} round trips over the in-process mesh):");
    let threaded_ns = one_shard_round_trip_ns(trips, true);
    let inline_ns = one_shard_round_trip_ns(trips, false);
    let gain = (1.0 - inline_ns / threaded_ns) * 100.0;
    println!("  threaded pipeline: {threaded_ns:.0} ns/round-trip");
    println!("  inline fast path:  {inline_ns:.0} ns/round-trip");
    json.push("one_shard_ping_pong", "threaded", &[("ns_per_round_trip", threaded_ns)]);
    json.push("one_shard_ping_pong", "inline", &[("ns_per_round_trip", inline_ns)]);
    println!(
        "  => inline latency improvement: {gain:.1}% {}",
        if gain >= 20.0 { "(≥20% target met)" } else { "(below 20% target)" }
    );

    // throughput sensitivity to the commit-batch size (the XLA engine's
    // amortisation knob) on the simulated cluster
    println!("\ncommit staging ablation (sim, batch_threshold sweep):");
    for &bt in &[1usize, 4, 16] {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = dur;
        cfg.wb = WbConfig { batch_threshold: bt, batch_flush_after: 200_000, ..WbConfig::default() };
        let r = run(&cfg);
        println!("  batch_threshold={bt:<3} {}", r.row());
        json.push("commit_staging", &format!("batch_threshold={bt}"), &[("throughput", r.throughput)]);
    }

    // ablation: replication degree f (group size 2f+1). WbCast's quorum
    // round trip scales with group size; latency is unchanged (still 3δ
    // message depth), throughput pays the extra fan-out.
    println!("\nreplication-degree ablation (WbCast, LAN, 400 clients, dest=3):");
    for &f in &[1usize, 2, 3] {
        let mut cfg = RunCfg::new(Proto::WbCast, 6, 400, 3, Net::Lan);
        cfg.f = f;
        cfg.duration = dur;
        let r = run(&cfg);
        println!("  f={f} (groups of {}): {}", 2 * f + 1, r.row());
        json.push("replication_degree", &format!("f={f}"), &[("throughput", r.throughput)]);
    }

    // ablation: payload size (the paper uses 20-byte messages; the CPU
    // model charges per byte, so this shows the payload-insensitivity of
    // the protocol itself)
    println!("\npayload-size ablation (WbCast, LAN, 400 clients, dest=3):");
    for &sz in &[20usize, 200, 2000] {
        let mut cfg = RunCfg::new(Proto::WbCast, 6, 400, 3, Net::Lan);
        cfg.duration = dur;
        let r = run_payload(&cfg, sz);
        println!("  payload={sz:<5} {}", r.row());
        json.push("payload_size", &format!("payload={sz}"), &[("throughput", r.throughput)]);
    }

    json.write(smoke);
}

/// Closed-loop saturation throughput of the real threaded
/// [`wbam::coordinator::ShardedRuntime`]: `shards` WbCast instances
/// behind each of the 6 member endpoints, clients on their own
/// endpoints, measured over `secs` of wall clock.
///
/// With `obs` set, every endpoint runtime gets the full live-metrics
/// pack attached and clients wall-clock-stamp their submissions — the
/// exact production `--metrics-addr` configuration — so the delta
/// against an `obs = None` run is the instrumentation overhead the
/// EXPERIMENTS.md ablation pins. Launches the mesh endpoints by hand
/// (rather than via [`Cluster::launch_hosts`]) because attaching
/// metrics is a per-runtime, pre-`run` operation.
fn real_cluster_throughput(shards: usize, n_clients: u32, secs: u64, obs: Option<Arc<CoreMetrics>>) -> f64 {
    let map = ShardMap::new(2, 1, shards);
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    let mut hosts: Vec<Vec<Box<dyn Node>>> = Vec::new();
    for e in map.endpoints() {
        let mut ns: Vec<Box<dyn Node>> = Vec::new();
        for p in map.hosted_by(e) {
            let s = map.shard_of(p).expect("member pid");
            ns.push(Box::new(WbNode::new(p, map.topo(s), wb)));
        }
        hosts.push(ns);
    }
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        let s = map.client_shard(pid);
        let cfg = ClientCfg {
            dest_groups: 2,
            resend_after: 2_000_000_000,
            stamp: obs.is_some(),
            ..Default::default()
        };
        hosts.push(vec![Box::new(Client::new(pid, map.topo(s), cfg, 0xBE5C + c as u64))]);
    }
    let t0 = Instant::now();
    let mesh = InProcMesh::new();
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for ns in hosts {
        let pids: Vec<Pid> = ns.iter().map(|n| n.pid()).collect();
        let ep = mesh.endpoint_hosting(&pids);
        let stop2 = Arc::clone(&stop);
        let cm = obs.clone();
        handles.push(std::thread::spawn(move || {
            let mut rt = ShardedRuntime::new(ns, ep);
            if let Some(cm) = cm {
                rt.attach_metrics(cm);
            }
            rt.run(stop2)
        }));
    }
    std::thread::sleep(std::time::Duration::from_secs(secs));
    stop.store(true, Ordering::Relaxed);
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for h in handles {
        nodes.extend(h.join().expect("endpoint thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut completed = 0usize;
    for n in &nodes {
        let any: &dyn Node = &**n;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    completed as f64 / wall
}

/// Closed-loop run of the same deployment over real localhost sockets:
/// 6 single-node member endpoints + `n_clients` client endpoints, all
/// bound through transport `kind`. Besides throughput and the steady-
/// state thread count (the thread-per-connection vs event-loop
/// comparison), measures client round-trip p50/p99 and the per-
/// multicast allocation / allocated-bytes / transport-syscall gauges
/// (counter deltas over the whole run divided by completed multicasts;
/// setup cost amortizes into noise at these counts). The syscall gauge
/// counts the transports' send/wake/wait paths — the threaded TCP
/// receive side hides reads behind `BufReader`, so its true total is
/// slightly higher than reported; epoll and io_uring are counted
/// exactly.
fn socket_cluster_run(kind: &'static str, n_clients: u32, secs: u64, base: u16) -> AblationRow {
    let topo = Topology::new(2, 1);
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    let mut addrs: HashMap<Pid, SocketAddr> = HashMap::new();
    for i in 0..6u32 {
        addrs.insert(Pid(i), format!("127.0.0.1:{}", base + i as u16).parse().unwrap());
    }
    for c in 0..n_clients {
        let pid = Pid(topo.first_client_pid().0 + c);
        addrs.insert(pid, format!("127.0.0.1:{}", base + 6 + c as u16).parse().unwrap());
    }
    let mut hosts: Vec<Vec<Box<dyn Node>>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            hosts.push(vec![Box::new(WbNode::new(p, topo.clone(), wb))]);
        }
    }
    for c in 0..n_clients {
        let pid = Pid(topo.first_client_pid().0 + c);
        let cfg = ClientCfg { dest_groups: 2, resend_after: 2_000_000_000, ..Default::default() };
        hosts.push(vec![Box::new(Client::new(pid, topo.clone(), cfg, 0xEB011 + c as u64))]);
    }
    let t0 = Instant::now();
    let allocs0 = ALLOCS.load(Ordering::Relaxed);
    let bytes0 = ALLOC_BYTES.load(Ordering::Relaxed);
    let sys0 = syscalls_observed();
    let cluster =
        Cluster::launch_hosts_over(hosts, None, FlushPolicy::default(), |pids| bind_kind(kind, pids[0], &addrs));
    std::thread::sleep(std::time::Duration::from_millis(500)); // listeners up, loop warm
    let threads = process_threads();
    std::thread::sleep(std::time::Duration::from_secs(secs));
    let nodes = cluster.shutdown();
    let wall = t0.elapsed().as_secs_f64();
    let allocs = ALLOCS.load(Ordering::Relaxed) - allocs0;
    let bytes = ALLOC_BYTES.load(Ordering::Relaxed) - bytes0;
    let syscalls = syscalls_observed() - sys0;
    let mut lat_ns: Vec<u64> = Vec::new();
    for n in &nodes {
        let any: &dyn Node = &**n;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            lat_ns.extend(c.completed.iter().map(|s| s.done_at.saturating_sub(s.sent_at)));
        }
    }
    lat_ns.sort_unstable();
    let completed = lat_ns.len();
    let pct = |p: f64| -> f64 {
        if completed == 0 {
            return f64::NAN;
        }
        let idx = ((completed - 1) as f64 * p) as usize;
        lat_ns[idx] as f64 / 1e6
    };
    let per = |v: u64| if completed == 0 { f64::NAN } else { v as f64 / completed as f64 };
    AblationRow {
        kind,
        throughput: completed as f64 / wall,
        p50_ms: pct(0.50),
        p99_ms: pct(0.99),
        threads,
        allocs_per_mc: per(allocs),
        alloc_kb_per_mc: per(bytes) / 1024.0,
        syscalls_per_mc: per(syscalls),
    }
}

/// Bind one endpoint over the named transport.
fn bind_kind(kind: &str, pid: Pid, addrs: &HashMap<Pid, SocketAddr>) -> Box<dyn Transport> {
    match kind {
        "tcp" => Box::new(TcpTransport::bind(pid, addrs.clone()).expect("bind tcp")),
        #[cfg(target_os = "linux")]
        "epoll" => Box::new(wbam::net::EpollTransport::bind(pid, addrs.clone()).expect("bind epoll")),
        #[cfg(target_os = "linux")]
        "uring" => Box::new(wbam::net::UringTransport::bind(pid, addrs.clone()).expect("bind uring")),
        other => panic!("unknown transport {other}"),
    }
}

/// This process's thread count per /proc (0 where unavailable).
fn process_threads() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// run() with an overridden client payload size.
fn run_payload(cfg: &RunCfg, payload: usize) -> wbam::harness::RunResult {
    use wbam::sim::{CpuCost, LanDelay, SimConfig, World};
    let topo = Topology::new(cfg.groups, cfg.f);
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            nodes.push(Box::new(WbNode::new(p, topo.clone(), cfg.wb)));
        }
    }
    for c in 0..cfg.clients {
        let pid = Pid(topo.first_client_pid().0 + c as u32);
        let ccfg = ClientCfg { dest_groups: cfg.dest_groups, payload, ..Default::default() };
        nodes.push(Box::new(Client::new(pid, topo.clone(), ccfg, cfg.seed ^ (c as u64 + 1))));
    }
    let mut w = World::new(
        topo,
        nodes,
        SimConfig {
            delay: Box::new(LanDelay::cloudlab()),
            cpu: CpuCost::lan_server(),
            seed: cfg.seed,
            record_full: false,
            coalesce: cfg.coalesce,
            flush: cfg.flush,
        },
    );
    w.run_until(cfg.duration);
    wbam::harness::summarize(cfg, &w.trace, (cfg.duration as f64 * cfg.warmup_frac) as u64, cfg.duration)
}
