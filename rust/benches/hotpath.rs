//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf): the WbCast leader
//! commit path driven through the reusable [`Outbox`] (zero per-event
//! effect allocations), the simulator event loop, the headline wire
//! batching / sharding ablations at saturation, the inline-vs-threaded
//! 1-shard runtime latency comparison, the adaptive flush-policy
//! ablation, and the thread-per-connection vs epoll transport ablation
//! over real localhost sockets (EXPERIMENTS.md §Transport ablation).
//!
//! Set `WBAM_SMOKE=1` for a seconds-long bit-rot check (tiny iteration
//! counts; the printed numbers are meaningless) — CI runs this mode.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::time::Instant;
use wbam::client::{Client, ClientCfg};
use wbam::coordinator::{one_shard_round_trip_ns, Cluster};
use wbam::harness::{run, Net, Proto, RunCfg};
use wbam::net::{TcpTransport, Transport};
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::{Node, Outbox};
use wbam::sim::MS;
use wbam::types::{Ballot, FlushPolicy, Gid, GidSet, MsgId, MsgMeta, Pid, ShardMap, Topology, Ts, Wire};

/// Drive one leader through the full ACCEPT/ACK/commit cycle in memory
/// (no network, no sim): the pure protocol-code cost per multicast. The
/// single outbox is reused across all events — the steady state does no
/// effect-vector allocation.
fn leader_commit_path(n: u32) -> f64 {
    let topo = Topology::new(2, 1);
    let mut leader = WbNode::new(Pid(0), topo.clone(), WbConfig::default());
    let b0 = Ballot::new(1, Pid(0));
    let b1 = Ballot::new(1, Pid(3));
    let dest = GidSet::from_iter([Gid(0), Gid(1)]);
    let mut out = Outbox::new();
    let t0 = Instant::now();
    for i in 1..=n {
        let m = MsgId::new(9, i);
        let meta = MsgMeta::new(m, dest, vec![0u8; 20]);
        // client MULTICAST
        leader.on_wire(Pid(9), Wire::Multicast { meta: meta.clone() }, 0, &mut out);
        std::hint::black_box(out.sends());
        out.clear();
        // own ACCEPT (self), remote leader's ACCEPT
        let lts0 = Ts::new(i as u64, Gid(0));
        let lts1 = Ts::new(i as u64, Gid(1));
        leader.on_wire(Pid(0), Wire::Accept { meta: meta.clone(), g: Gid(0), bal: b0, lts: lts0 }, 0, &mut out);
        out.clear();
        leader.on_wire(Pid(3), Wire::Accept { meta, g: Gid(1), bal: b1, lts: lts1 }, 0, &mut out);
        out.clear();
        // quorum of ACCEPT_ACKs from both groups
        let bals = vec![(Gid(0), b0), (Gid(1), b1)];
        for p in [Pid(0), Pid(1), Pid(3), Pid(4)] {
            let g = topo.group_of(p).unwrap();
            leader.on_wire(p, Wire::AcceptAck { m, g, bals: bals.clone() }, 0, &mut out);
            std::hint::black_box(out.sends());
            out.clear();
        }
        assert_eq!(leader.stats.committed, i as u64);
    }
    t0.elapsed().as_nanos() as f64 / n as f64
}

fn main() {
    println!("== L3 hot path ==\n");

    // WBAM_SMOKE=1: tiny iteration counts so CI can catch bench bit-rot
    // in seconds (the numbers are not meaningful in this mode)
    let smoke = std::env::var("WBAM_SMOKE").is_ok();
    if smoke {
        println!("(smoke mode: tiny iteration counts, numbers are meaningless)\n");
    }
    let commit_iters = if smoke { 2_000 } else { 50_000 };
    let dur = if smoke { 30 * MS } else { 300 * MS };
    let secs = if smoke { 1 } else { 3 };
    let trips = if smoke { 300 } else { 5_000 };

    let per_commit = leader_commit_path(commit_iters);
    println!("leader commit path (in-memory, 2 groups, reused outbox): {per_commit:.0} ns/multicast");

    // simulator event throughput under load
    let t0 = Instant::now();
    let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
    cfg.duration = dur;
    let r = run(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    let events = r.completed as f64 * r.msgs_per_multicast;
    println!(
        "saturated LAN sim (10 groups, 800 clients): {:.0} virtual msgs in {wall:.2}s wall = {:.2} M events/s",
        events,
        events / wall / 1e6
    );
    println!("  {}", r.row());

    // headline ablation: destination-coalesced wire batching on vs off at
    // saturation. Frames amortise the per-message recv/send CPU charges
    // (and, on real transports, the per-message encode + syscall), which
    // is where the knee of the throughput curve comes from. Acceptance
    // bar for the refactor: ≥20% more completed multicasts with
    // coalescing on.
    println!("\nwire-batching ablation (sim, 10 groups, 800 clients, dest=4, commit batch 16):");
    let mut thru = [0f64; 2];
    for (i, &co) in [false, true].iter().enumerate() {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = dur;
        cfg.coalesce = co;
        cfg.wb = WbConfig { batch_threshold: 16, batch_flush_after: 200_000, ..WbConfig::default() };
        let r = run(&cfg);
        thru[i] = r.throughput;
        println!("  coalesce={:<5} {}", co, r.row());
    }
    let gain = (thru[1] / thru[0] - 1.0) * 100.0;
    println!(
        "  => coalescing throughput gain at saturation: {gain:+.1}% {}",
        if gain >= 20.0 { "(≥20% target met)" } else { "(below 20% target)" }
    );

    // adaptive per-link coalescing at the same saturated operating
    // point: holding a link for up to 200 µs under load folds wires from
    // *several* events into one frame (flush-per-cycle only merges one
    // event's fan-out), trading bounded extra latency for a higher CPU
    // knee. See EXPERIMENTS.md §Coalescing knees.
    println!("\nadaptive flush-policy ablation (sim, 10 groups, 800 clients, dest=4):");
    let policies: [(&str, FlushPolicy); 3] = [
        ("immediate        ", FlushPolicy::immediate()),
        ("adaptive 200us   ", FlushPolicy { max_delay_us: 200, max_bytes: 1 << 20, flush_on_quiet: true }),
        ("adaptive no-quiet", FlushPolicy { max_delay_us: 200, max_bytes: 1 << 20, flush_on_quiet: false }),
    ];
    let mut athru = [0f64; 3];
    for (i, (name, p)) in policies.iter().enumerate() {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = dur;
        cfg.flush = *p;
        let r = run(&cfg);
        athru[i] = r.throughput;
        println!("  {name} {}", r.row());
    }
    println!(
        "  => adaptive (quiet) vs immediate at saturation: {:+.1}%; strict window: {:+.1}%",
        (athru[1] / athru[0] - 1.0) * 100.0,
        (athru[2] / athru[0] - 1.0) * 100.0
    );

    // leader sharding: S independent protocol instances behind each
    // endpoint, clients partitioned by client id. Every shard is its own
    // single-threaded server in the sim's CPU model, so the saturation
    // knee lifts with the shard count. Acceptance bar: ≥1.5x completed
    // multicasts at saturation with 4 shards.
    println!("\nleader-sharding ablation (sim, 2 groups, 256 clients, dest=2, saturation):");
    let mut sharded = [0f64; 2];
    for (i, &s) in [1usize, 4].iter().enumerate() {
        let mut cfg = RunCfg::new(Proto::WbCast, 2, 256, 2, Net::Lan);
        cfg.duration = dur;
        cfg.shards = s;
        let r = run(&cfg);
        sharded[i] = r.throughput;
        println!("  shards={s:<2} {}", r.row());
    }
    let gain = sharded[1] / sharded[0];
    println!(
        "  => 1-shard vs 4-shard saturation throughput: {gain:.2}x {}",
        if gain >= 1.5 { "(≥1.5x target met)" } else { "(below 1.5x target)" }
    );

    // the same comparison on the real threaded ShardedRuntime over the
    // in-process mesh: one worker thread per shard behind each endpoint,
    // so the actual speedup is bounded by the host's core count
    println!("\nsharded runtime (real threads, 2 groups x 3 replicas, 64 clients, dest=2, {secs}s):");
    for &s in &[1usize, 4] {
        let thru = real_cluster_throughput(s, 64, secs);
        println!("  shards={s:<2} {thru:.0} multicasts/s");
    }

    // transport ablation (EXPERIMENTS.md §Transport ablation): the same
    // closed-loop deployment over real localhost sockets, once on the
    // thread-per-connection TCP transport and once on the epoll event
    // loop. The thread column is the O(connections)-vs-O(1) cost made
    // visible: tcp holds one reader thread per accepted connection,
    // epoll exactly one loop thread per endpoint. Acceptance bar for
    // the epoll transport: >= 1x the threaded throughput at the
    // saturation knee (it must not cost throughput to save the threads).
    let tcli = if smoke { 8 } else { 32 };
    println!("\ntransport ablation (real sockets, 2 groups x 3 replicas, {tcli} clients, dest=2, {secs}s):");
    let mut tthru = [0f64; 2];
    for (i, &kind) in ["tcp", "epoll"].iter().enumerate() {
        if kind == "epoll" && !cfg!(target_os = "linux") {
            println!("  epoll  (skipped: requires linux)");
            continue;
        }
        // process-keyed bases (like the unit tests' next_port) so a
        // concurrent or back-to-back run cannot collide on a listener
        let base = 33000 + (std::process::id() % 300) as u16 * 96 + (i as u16) * 48;
        let (thru, threads) = socket_cluster_throughput(kind, tcli, secs, base);
        tthru[i] = thru;
        println!("  {kind:<6} {thru:.0} multicasts/s   ({threads} process threads at steady state)");
    }
    if tthru[0] > 0.0 && tthru[1] > 0.0 {
        let gain = tthru[1] / tthru[0];
        println!(
            "  => epoll vs thread-per-conn throughput: {gain:.2}x {}",
            if gain >= 1.0 { "(≥1x target met)" } else { "(below 1x target)" }
        );
    }

    // inline 1-shard fast path vs the threaded worker/flusher pipeline
    // on single-message latency: the inline loop removes two channel
    // hops and two thread wakeups per message. Acceptance bar: >= 20%
    // lower round-trip latency, pinned (via the same shared harness) as
    // coordinator::tests::inline_single_shard_beats_threaded_on_latency.
    println!("\n1-shard runtime ping-pong ({trips} round trips over the in-process mesh):");
    let threaded_ns = one_shard_round_trip_ns(trips, true);
    let inline_ns = one_shard_round_trip_ns(trips, false);
    let gain = (1.0 - inline_ns / threaded_ns) * 100.0;
    println!("  threaded pipeline: {threaded_ns:.0} ns/round-trip");
    println!("  inline fast path:  {inline_ns:.0} ns/round-trip");
    println!(
        "  => inline latency improvement: {gain:.1}% {}",
        if gain >= 20.0 { "(≥20% target met)" } else { "(below 20% target)" }
    );

    // throughput sensitivity to the commit-batch size (the XLA engine's
    // amortisation knob) on the simulated cluster
    println!("\ncommit staging ablation (sim, batch_threshold sweep):");
    for &bt in &[1usize, 4, 16] {
        let mut cfg = RunCfg::new(Proto::WbCast, 10, 800, 4, Net::Lan);
        cfg.duration = dur;
        cfg.wb = WbConfig { batch_threshold: bt, batch_flush_after: 200_000, ..WbConfig::default() };
        let r = run(&cfg);
        println!("  batch_threshold={bt:<3} {}", r.row());
    }

    // ablation: replication degree f (group size 2f+1). WbCast's quorum
    // round trip scales with group size; latency is unchanged (still 3δ
    // message depth), throughput pays the extra fan-out.
    println!("\nreplication-degree ablation (WbCast, LAN, 400 clients, dest=3):");
    for &f in &[1usize, 2, 3] {
        let mut cfg = RunCfg::new(Proto::WbCast, 6, 400, 3, Net::Lan);
        cfg.f = f;
        cfg.duration = dur;
        let r = run(&cfg);
        println!("  f={f} (groups of {}): {}", 2 * f + 1, r.row());
    }

    // ablation: payload size (the paper uses 20-byte messages; the CPU
    // model charges per byte, so this shows the payload-insensitivity of
    // the protocol itself)
    println!("\npayload-size ablation (WbCast, LAN, 400 clients, dest=3):");
    for &sz in &[20usize, 200, 2000] {
        let mut cfg = RunCfg::new(Proto::WbCast, 6, 400, 3, Net::Lan);
        cfg.duration = dur;
        let r = run_payload(&cfg, sz);
        println!("  payload={sz:<5} {}", r.row());
    }
}

/// Closed-loop saturation throughput of the real threaded
/// [`wbam::coordinator::ShardedRuntime`]: `shards` WbCast instances
/// behind each of the 6 member endpoints, clients on their own
/// endpoints, measured over `secs` of wall clock.
fn real_cluster_throughput(shards: usize, n_clients: u32, secs: u64) -> f64 {
    let map = ShardMap::new(2, 1, shards);
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    let mut hosts: Vec<Vec<Box<dyn Node>>> = Vec::new();
    for e in map.endpoints() {
        let mut ns: Vec<Box<dyn Node>> = Vec::new();
        for p in map.hosted_by(e) {
            let s = map.shard_of(p).expect("member pid");
            ns.push(Box::new(WbNode::new(p, map.topo(s), wb)));
        }
        hosts.push(ns);
    }
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        let s = map.client_shard(pid);
        let cfg = ClientCfg { dest_groups: 2, resend_after: 2_000_000_000, ..Default::default() };
        hosts.push(vec![Box::new(Client::new(pid, map.topo(s), cfg, 0xBE5C + c as u64))]);
    }
    let t0 = Instant::now();
    let cluster = Cluster::launch_hosts(hosts, None);
    std::thread::sleep(std::time::Duration::from_secs(secs));
    let nodes = cluster.shutdown();
    let wall = t0.elapsed().as_secs_f64();
    let mut completed = 0usize;
    for n in &nodes {
        let any: &dyn Node = &**n;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    completed as f64 / wall
}

/// Closed-loop throughput of the same deployment over real localhost
/// sockets: 6 single-node member endpoints + `n_clients` client
/// endpoints, all bound through transport `kind`. Returns
/// `(multicasts/s, process thread count at steady state)` — the thread
/// count is the thread-per-connection vs event-loop comparison.
fn socket_cluster_throughput(kind: &str, n_clients: u32, secs: u64, base: u16) -> (f64, usize) {
    let topo = Topology::new(2, 1);
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    let mut addrs: HashMap<Pid, SocketAddr> = HashMap::new();
    for i in 0..6u32 {
        addrs.insert(Pid(i), format!("127.0.0.1:{}", base + i as u16).parse().unwrap());
    }
    for c in 0..n_clients {
        let pid = Pid(topo.first_client_pid().0 + c);
        addrs.insert(pid, format!("127.0.0.1:{}", base + 6 + c as u16).parse().unwrap());
    }
    let mut hosts: Vec<Vec<Box<dyn Node>>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            hosts.push(vec![Box::new(WbNode::new(p, topo.clone(), wb))]);
        }
    }
    for c in 0..n_clients {
        let pid = Pid(topo.first_client_pid().0 + c);
        let cfg = ClientCfg { dest_groups: 2, resend_after: 2_000_000_000, ..Default::default() };
        hosts.push(vec![Box::new(Client::new(pid, topo.clone(), cfg, 0xEB011 + c as u64))]);
    }
    let t0 = Instant::now();
    let cluster =
        Cluster::launch_hosts_over(hosts, None, FlushPolicy::default(), |pids| bind_kind(kind, pids[0], &addrs));
    std::thread::sleep(std::time::Duration::from_millis(500)); // listeners up, loop warm
    let threads = process_threads();
    std::thread::sleep(std::time::Duration::from_secs(secs));
    let nodes = cluster.shutdown();
    let wall = t0.elapsed().as_secs_f64();
    let mut completed = 0usize;
    for n in &nodes {
        let any: &dyn Node = &**n;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    (completed as f64 / wall, threads)
}

/// Bind one endpoint over the named transport.
fn bind_kind(kind: &str, pid: Pid, addrs: &HashMap<Pid, SocketAddr>) -> Box<dyn Transport> {
    match kind {
        "tcp" => Box::new(TcpTransport::bind(pid, addrs.clone()).expect("bind tcp")),
        #[cfg(target_os = "linux")]
        "epoll" => Box::new(wbam::net::EpollTransport::bind(pid, addrs.clone()).expect("bind epoll")),
        other => panic!("unknown transport {other}"),
    }
}

/// This process's thread count per /proc (0 where unavailable).
fn process_threads() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// run() with an overridden client payload size.
fn run_payload(cfg: &RunCfg, payload: usize) -> wbam::harness::RunResult {
    use wbam::sim::{CpuCost, LanDelay, SimConfig, World};
    let topo = Topology::new(cfg.groups, cfg.f);
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            nodes.push(Box::new(WbNode::new(p, topo.clone(), cfg.wb)));
        }
    }
    for c in 0..cfg.clients {
        let pid = Pid(topo.first_client_pid().0 + c as u32);
        let ccfg = ClientCfg { dest_groups: cfg.dest_groups, payload, ..Default::default() };
        nodes.push(Box::new(Client::new(pid, topo.clone(), ccfg, cfg.seed ^ (c as u64 + 1))));
    }
    let mut w = World::new(
        topo,
        nodes,
        SimConfig {
            delay: Box::new(LanDelay::cloudlab()),
            cpu: CpuCost::lan_server(),
            seed: cfg.seed,
            record_full: false,
            coalesce: cfg.coalesce,
            flush: cfg.flush,
        },
    );
    w.run_until(cfg.duration);
    wbam::harness::summarize(cfg, &w.trace, (cfg.duration as f64 * cfg.warmup_frac) as u64, cfg.duration)
}
