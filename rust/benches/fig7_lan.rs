//! Fig. 7 / Fig. 9 (extended): LAN latency–throughput curves.
//!
//! Paper setup: 10 groups x 3 replicas on CloudLab (≈0.1 ms RTT), a
//! varying number of closed-loop clients multicasting 20-byte messages
//! to a fixed number of destination groups; 3 protocols: FT-Skeen,
//! FastCast, WbCast. We regenerate the same series on the calibrated
//! LAN simulator. Absolute numbers differ from the paper's testbed; the
//! *shape* — WbCast wins on both axes, FastCast ≈ FT-Skeen in LAN (its
//! parallel paths cost extra messages) — is the reproduction target.
//!
//! `cargo bench --bench fig7_lan` (set WBAM_BENCH_FULL=1 for the full
//! client sweep and the Fig. 9 destination-group set).

use wbam::harness::{run, Net, Proto, RunCfg};
use wbam::sim::MS;

fn main() {
    let full = std::env::var("WBAM_BENCH_FULL").is_ok();
    let dests: &[usize] = if full { &[1, 2, 3, 4, 5, 6, 7, 8, 10] } else { &[1, 4, 7] };
    let clients: &[usize] =
        if full { &[50, 100, 200, 400, 700, 1000, 1500, 2000] } else { &[50, 200, 600, 1000] };

    println!("== Fig. 7{} — LAN (0.1 ms RTT), 10 groups x 3 replicas ==", if full { "+9" } else { "" });
    for &d in dests {
        println!("\n-- {d} destination group(s) --");
        let mut at1000 = Vec::new();
        for proto in Proto::EVAL {
            for &c in clients {
                let mut cfg = RunCfg::new(proto, 10, c, d, Net::Lan);
                cfg.duration = 400 * MS;
                cfg.warmup_frac = 0.25;
                cfg.seed = 7;
                let r = run(&cfg);
                println!("{}", r.row());
                if c == 1000 || (!clients.contains(&1000) && c == *clients.last().unwrap()) {
                    at1000.push((proto, r.mean_lat_ms, r.throughput));
                }
            }
        }
        // headline comparison at the 1000-client mark (paper: WbCast
        // outperforms FastCast 1.2-3.5x, 2.15x on average)
        let wb = at1000.iter().find(|x| x.0 == Proto::WbCast).unwrap();
        let fc = at1000.iter().find(|x| x.0 == Proto::FastCast).unwrap();
        println!(
            ">> dest={d} @{} clients: WbCast vs FastCast — latency {:.2}x lower, throughput {:.2}x higher",
            clients.last().unwrap(),
            fc.1 / wb.1,
            wb.2 / fc.2
        );
    }
}
