//! White-box probes of the paper's Fig. 6 invariants, checked directly
//! against protocol state after randomized runs (complementing the
//! trace-level checks in `wbam::invariants`).

use wbam::harness::{build_world, Net, Proto, RunCfg};
use wbam::invariants;
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::sim::{World, MS};
use wbam::types::{Phase, Pid, Topology, Ts};
use wbam::util::prop;

fn wb_world(r: &mut wbam::util::Rng, crash: bool) -> (World, Topology) {
    let delta = MS;
    let groups = r.range(2, 3) as usize;
    let mut cfg = RunCfg::new(Proto::WbCast, groups, 3, 2, Net::Theory { delta });
    cfg.seed = r.next_u64();
    cfg.max_requests = Some(12);
    cfg.record_full = true;
    cfg.wb = if crash { WbConfig::with_failures(delta) } else { WbConfig::default() };
    cfg.resend_after = if crash { 40 * delta } else { 0 };
    let topo = Topology::new(groups, 1);
    let mut w = build_world(&cfg);
    if crash {
        let victim = Pid(r.below((groups * 3) as u64) as u32);
        w.crash_at(victim, r.range(1, 50) * delta);
        w.run_until(4_000 * delta);
    } else {
        w.run_to_quiescence(50_000_000);
    }
    (w, topo)
}

/// Invariants 3(a,b) + 4 at the state level: all processes that know a
/// committed message agree on its lts within a group and its gts across
/// groups; gts values are unique.
#[test]
fn state_agreement_on_timestamps() {
    prop::check(12, |r| {
        let crash = r.chance(0.5);
        let (w, topo) = wb_world(r, crash);
        invariants::assert_safe(&w.trace);
        let crashed: Vec<Pid> = w.trace.crashes.iter().map(|&(_, p)| p).collect();
        let mut gts_of: std::collections::HashMap<wbam::types::MsgId, Ts> = Default::default();
        let mut seen_gts: std::collections::HashSet<Ts> = Default::default();
        for g in topo.gids() {
            let mut lts_of: std::collections::HashMap<wbam::types::MsgId, Ts> = Default::default();
            for &p in topo.members(g) {
                if crashed.contains(&p) {
                    continue;
                }
                let n = w.node_as::<WbNode>(p);
                for (m, gts) in n.committed_view() {
                    // gts agreement across every process (Invariant 3b)
                    let e = gts_of.entry(m).or_insert(gts);
                    assert_eq!(*e, gts, "{m:?} gts mismatch at {p:?}");
                    if let Some(lts) = n.lts_view(m) {
                        let e = lts_of.entry(m).or_insert(lts);
                        assert_eq!(*e, lts, "{m:?} lts mismatch within {g:?} at {p:?}");
                    }
                }
            }
        }
        // gts uniqueness (Invariant 4)
        for (&m, &gts) in &gts_of {
            assert!(seen_gts.insert(gts), "duplicate gts {gts:?} (one at {m:?})");
        }
    });
}

/// Invariant 14: at any process, a committed message's global timestamp
/// never exceeds the clock; Invariant 13: lts ≤ gts.
#[test]
fn clock_dominates_committed_gts() {
    prop::check(12, |r| {
        let crash = r.chance(0.5);
        let (w, topo) = wb_world(r, crash);
        let crashed: Vec<Pid> = w.trace.crashes.iter().map(|&(_, p)| p).collect();
        for g in topo.gids() {
            for &p in topo.members(g) {
                if crashed.contains(&p) {
                    continue;
                }
                let n = w.node_as::<WbNode>(p);
                for (m, gts) in n.committed_view() {
                    assert!(n.clock() >= gts.time(), "{p:?}: clock {} < gts {gts:?} of {m:?}", n.clock());
                    if let Some(lts) = n.lts_view(m) {
                        assert!(lts <= gts, "{p:?}: lts {lts:?} > gts {gts:?} for {m:?}");
                    }
                }
            }
        }
    });
}

/// Invariant 2(a,b) observable: once a message is delivered anywhere,
/// every *correct* group member that participates further (same cballot
/// era) holds it at phase ≥ ACCEPTED with the agreed local timestamp —
/// after quiescence all correct members of destination groups have it
/// COMMITTED (Termination strengthens this).
#[test]
fn delivered_messages_persist_at_quorums() {
    prop::check(10, |r| {
        let (w, topo) = wb_world(r, false);
        invariants::assert_correct(&w.trace);
        for d in &w.trace.deliveries {
            let Some((_, dest)) = w.trace.multicasts.get(&d.m) else { continue };
            for g in dest.iter() {
                let committed = topo
                    .members(g)
                    .iter()
                    .filter(|&&p| {
                        let n = w.node_as::<WbNode>(p);
                        n.phase_of(d.m) == Phase::Committed
                    })
                    .count();
                assert!(committed >= topo.quorum(), "{:?} not persisted at a quorum of {g:?}", d.m);
            }
        }
    });
}

/// After a crash + full recovery, ballots are consistent: every correct
/// member of the affected group ends on the same cballot, led by the
/// surviving leader (Invariant 6's stable-leader state).
#[test]
fn recovery_converges_to_single_ballot() {
    prop::check(10, |r| {
        let (w, topo) = wb_world(r, true);
        invariants::assert_safe(&w.trace);
        let crashed: Vec<Pid> = w.trace.crashes.iter().map(|&(_, p)| p).collect();
        for g in topo.gids() {
            let correct: Vec<Pid> =
                topo.members(g).iter().copied().filter(|p| !crashed.contains(p)).collect();
            let bals: Vec<_> = correct.iter().map(|&p| w.node_as::<WbNode>(p).cballot()).collect();
            assert!(bals.windows(2).all(|x| x[0] == x[1]), "{g:?} split ballots: {bals:?}");
            let leader = bals[0].leader();
            assert!(correct.contains(&leader), "{g:?} led by crashed {leader:?}");
            let n_leaders = correct.iter().filter(|&&p| w.node_as::<WbNode>(p).is_leader()).count();
            assert_eq!(n_leaders, 1, "{g:?} has {n_leaders} leaders");
        }
    });
}
