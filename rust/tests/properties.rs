//! Randomized property tests: safety (Validity, Integrity, Ordering —
//! the observable consequences of Invariants 1–5) and Termination over
//! randomly generated deployments, workloads, schedules and failure
//! patterns. Failing cases report a replay seed.

use wbam::harness::{build_world, Net, Proto, RunCfg};
use wbam::invariants;
use wbam::protocols::wbcast::WbConfig;
use wbam::sim::MS;
use wbam::types::{Gid, GidSet, Pid};
use wbam::util::prop;

/// Random failure-free runs across all four protocols, LAN jitter.
#[test]
fn safety_and_termination_random_failure_free() {
    prop::check(25, |r| {
        let proto = *r.choose(&Proto::ALL);
        let groups = r.range(1, 4) as usize;
        let clients = r.range(1, 6) as usize;
        let dest = r.range(1, groups as u64) as usize;
        let mut cfg = RunCfg::new(proto, groups, clients, dest, Net::Lan);
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(r.range(3, 25) as u32);
        cfg.record_full = true;
        let mut w = build_world(&cfg);
        w.run_to_quiescence(60_000_000);
        invariants::assert_correct(&w.trace);
    });
}

/// Random WAN runs (large heterogeneous delays stress cross-group
/// reordering).
#[test]
fn safety_random_wan() {
    prop::check(10, |r| {
        let proto = *r.choose(&Proto::EVAL);
        let groups = r.range(2, 5) as usize;
        let mut cfg = RunCfg::new(proto, groups, 4, 2, Net::Wan);
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(8);
        cfg.record_full = true;
        let mut w = build_world(&cfg);
        w.run_to_quiescence(30_000_000);
        invariants::assert_correct(&w.trace);
    });
}

/// WbCast with random single-crash injection (≤ f per group): safety
/// always; termination among correct processes.
#[test]
fn wbcast_random_crashes() {
    prop::check(15, |r| {
        let delta = MS;
        let groups = r.range(2, 3) as usize;
        let mut cfg = RunCfg::new(Proto::WbCast, groups, 3, 2, Net::Theory { delta });
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(15);
        cfg.record_full = true;
        cfg.wb = WbConfig::with_failures(delta);
        cfg.resend_after = 40 * delta;
        let mut w = build_world(&cfg);
        // crash one random member (possibly a leader) at a random time
        let victim = Pid(r.below((groups * 3) as u64) as u32);
        let when = r.range(1, 60) * delta;
        w.crash_at(victim, when);
        w.run_until(4_000 * delta);
        invariants::assert_safe(&w.trace);
        let vs = invariants::check_termination(&w.trace);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(w.trace.incomplete(), 0, "stuck messages");
    });
}

/// WbCast with aggressive client retransmissions (duplicates everywhere)
/// must not double-deliver or reorder.
#[test]
fn wbcast_duplicate_storms() {
    prop::check(15, |r| {
        let delta = MS;
        let mut cfg = RunCfg::new(Proto::WbCast, 3, 4, 2, Net::Theory { delta });
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(10);
        cfg.record_full = true;
        // resend faster than the 3δ commit latency → constant duplicates
        cfg.resend_after = r.range(1, 3) * delta;
        let mut w = build_world(&cfg);
        w.run_to_quiescence(60_000_000);
        invariants::assert_correct(&w.trace);
    });
}

/// Genuineness (§II minimality): processes outside dest(m) ∪ {sender}
/// receive no protocol traffic when every multicast avoids their groups.
#[test]
fn genuineness_non_destinations_stay_silent() {
    for proto in Proto::EVAL {
        let topo = wbam::types::Topology::new(4, 1);
        let mut nodes: Vec<Box<dyn wbam::protocols::Node>> = Vec::new();
        for g in topo.gids() {
            for &p in topo.members(g) {
                match proto {
                    Proto::FtSkeen => nodes.push(Box::new(wbam::protocols::ftskeen::FtSkeenNode::new(p, topo.clone()))),
                    Proto::FastCast => nodes.push(Box::new(wbam::protocols::fastcast::FastCastNode::new(p, topo.clone()))),
                    _ => nodes.push(Box::new(wbam::protocols::wbcast::WbNode::new(p, topo.clone(), WbConfig::default()))),
                }
            }
        }
        let both = GidSet::from_iter([Gid(0), Gid(1)]);
        let script: Vec<(u64, GidSet)> = (0..10).map(|i| (i * MS, both)).collect();
        nodes.push(Box::new(wbam::harness::ScriptedClient::new(topo.first_client_pid(), topo.clone(), script)));
        let mut w = wbam::sim::World::new(topo.clone(), nodes, wbam::sim::SimConfig::theory(MS));
        w.run_to_quiescence(1_000_000);
        invariants::assert_safe(&w.trace);
        // members of g2 and g3 never participate
        for g in [Gid(2), Gid(3)] {
            for &p in topo.members(g) {
                let n = w.arrivals.get(&p).copied().unwrap_or(0);
                assert_eq!(n, 0, "{}: non-destination {p:?} received {n} messages", proto.name());
            }
        }
    }
}

/// Deterministic replay: identical seeds produce identical traces.
#[test]
fn simulation_is_deterministic() {
    prop::check(5, |r| {
        let seed = r.next_u64();
        let mk = || {
            let mut cfg = RunCfg::new(Proto::WbCast, 3, 4, 2, Net::Lan);
            cfg.seed = seed;
            cfg.max_requests = Some(20);
            cfg.record_full = true;
            let mut w = build_world(&cfg);
            w.run_to_quiescence(30_000_000);
            (w.trace.sends, w.trace.delivered_count, w.trace.mean_latency())
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
    });
}

/// Wire batching is schedule-transparent in the constant-δ, zero-CPU
/// setting: the same `(nodes, config, seed)` with destination coalescing
/// on vs off must produce identical per-process delivery orders (frames
/// only merge same-destination sends of one event, whose inner FIFO
/// order the batch preserves), and the invariant checker must be green
/// in both. Covers commit staging both off (`batch_threshold = 1`) and
/// on (8), which is what pumps multi-wire frames through `DELIVER`
/// fan-out.
#[test]
fn batching_preserves_delivery_order() {
    for &seed in &[3u64, 0x5EED, 0xB47C4] {
        for &threshold in &[1usize, 8] {
            let run_one = |coalesce: bool| {
                let mut cfg = RunCfg::new(Proto::WbCast, 3, 4, 2, Net::Theory { delta: MS });
                cfg.seed = seed;
                cfg.max_requests = Some(25);
                cfg.record_full = true;
                cfg.coalesce = coalesce;
                cfg.wb = WbConfig { batch_threshold: threshold, batch_flush_after: 5 * MS, ..WbConfig::default() };
                let mut w = build_world(&cfg);
                w.run_to_quiescence(60_000_000);
                invariants::assert_correct(&w.trace);
                // per-process delivery sequence: (pid, message, gts)
                let mut per_pid: std::collections::BTreeMap<Pid, Vec<_>> = Default::default();
                for d in &w.trace.deliveries {
                    per_pid.entry(d.pid).or_default().push((d.m, d.gts));
                }
                per_pid
            };
            let batched = run_one(true);
            let unbatched = run_one(false);
            assert_eq!(
                batched, unbatched,
                "delivery orders diverged between coalesce on/off (seed {seed:#x}, batch_threshold {threshold})"
            );
        }
    }
}

/// Adaptive coalescing is FIFO-transparent per link: under ANY
/// [`FlushPolicy`](wbam::types::FlushPolicy) every receiver observes
/// every sender's wires in exactly the send order produced by the
/// flush-every-cycle baseline, no matter how the policy carves them into
/// frames (delay windows, `max_bytes` overflow, quiet flushes; the 8 MiB
/// splitter/`max_bytes` boundary interaction is pinned at unit level in
/// `protocols::outbox`). Reuses the PR 1 batching-equivalence harness
/// idea with open-loop senders so both runs generate identical traffic.
#[test]
fn flush_policies_preserve_per_link_fifo() {
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};
    use wbam::protocols::{Node, Outbox, TimerKind};
    use wbam::sim::{ConstDelay, CpuCost, SimConfig, World};
    use wbam::types::{FlushPolicy, MsgId, MsgMeta, Topology, Wire};
    use wbam::util::Rng;

    /// Open-loop sender: random bursts to random peers on a fixed timer
    /// cadence — its traffic is a pure function of its seed, so the
    /// baseline and adaptive runs see identical send sequences.
    struct Blaster {
        pid: Pid,
        peers: Vec<Pid>,
        rng: Rng,
        bursts: u32,
        seq: u32,
    }
    impl Node for Blaster {
        fn pid(&self) -> Pid {
            self.pid
        }
        fn on_start(&mut self, _n: u64, out: &mut Outbox) {
            out.timer(TimerKind::ClientNext, 50_000);
        }
        fn on_wire(&mut self, _f: Pid, _w: Wire, _n: u64, _o: &mut Outbox) {}
        fn on_timer(&mut self, _t: TimerKind, _n: u64, out: &mut Outbox) {
            if self.bursts == 0 {
                return;
            }
            self.bursts -= 1;
            for _ in 0..self.rng.range(1, 6) {
                let to = *self.rng.choose(&self.peers);
                self.seq += 1;
                let payload = vec![0u8; self.rng.below(200) as usize];
                out.send(
                    to,
                    Wire::Multicast {
                        meta: MsgMeta::new(MsgId::new(self.pid.0, self.seq), GidSet::single(Gid(0)), payload),
                    },
                );
            }
            out.timer(TimerKind::ClientNext, 30_000);
        }
    }
    /// Records the per-link order in which inner wires reach it.
    struct Recorder {
        pid: Pid,
        seen: Arc<Mutex<BTreeMap<(Pid, Pid), Vec<u64>>>>,
    }
    impl Node for Recorder {
        fn pid(&self) -> Pid {
            self.pid
        }
        fn on_start(&mut self, _n: u64, _o: &mut Outbox) {}
        fn on_wire(&mut self, from: Pid, wire: Wire, _n: u64, _o: &mut Outbox) {
            if let Wire::Multicast { meta } = wire {
                self.seen.lock().unwrap().entry((from, self.pid)).or_default().push(meta.id.0);
            }
        }
        fn on_timer(&mut self, _t: TimerKind, _n: u64, _o: &mut Outbox) {}
    }

    let run_one = |policy: FlushPolicy, seed: u64| -> BTreeMap<(Pid, Pid), Vec<u64>> {
        let seen = Arc::new(Mutex::new(BTreeMap::new()));
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        for p in [Pid(0), Pid(1), Pid(2)] {
            nodes.push(Box::new(Recorder { pid: p, seen: Arc::clone(&seen) }));
        }
        for p in [Pid(10), Pid(11)] {
            nodes.push(Box::new(Blaster {
                pid: p,
                peers: vec![Pid(0), Pid(1), Pid(2)],
                rng: Rng::new(seed ^ p.0 as u64),
                bursts: 30,
                seq: 0,
            }));
        }
        let cfg = SimConfig {
            delay: Box::new(ConstDelay(1_000_000)),
            cpu: CpuCost::lan_server(),
            seed,
            record_full: false,
            coalesce: true,
            flush: policy,
        };
        let mut w = World::new(Topology::new(1, 0), nodes, cfg);
        w.run_to_quiescence(10_000_000);
        let recorded = seen.lock().unwrap().clone();
        drop(w); // the recorders hold clones of `seen`; drop before return
        recorded
    };

    prop::check(8, |r| {
        let seed = r.next_u64();
        let baseline = run_one(FlushPolicy::immediate(), seed);
        assert!(!baseline.is_empty(), "blasters produced no traffic");
        let policy = FlushPolicy {
            max_delay_us: r.range(1, 400),
            // sometimes small enough that single wires overflow the link
            // instantly — the other boundary of the max_bytes knob
            max_bytes: if r.chance(0.5) { r.range(32, 600) as usize } else { usize::MAX },
            flush_on_quiet: r.chance(0.5),
        };
        let adaptive = run_one(policy, seed);
        assert_eq!(baseline, adaptive, "per-link arrival order diverged under {policy:?}");
    });
}

/// WbCast end-to-end safety (Validity/Integrity/Ordering + termination)
/// is preserved under random adaptive flush policies — held frames delay
/// protocol messages but never reorder a link or lose a wire.
#[test]
fn wbcast_safe_under_random_flush_policies() {
    use wbam::types::FlushPolicy;
    prop::check(12, |r| {
        let policy = FlushPolicy {
            max_delay_us: r.range(1, 500),
            max_bytes: if r.chance(0.3) { r.range(64, 4096) as usize } else { usize::MAX },
            flush_on_quiet: r.chance(0.5),
        };
        let mut cfg = RunCfg::new(Proto::WbCast, 3, 4, 2, Net::Lan);
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(12);
        cfg.record_full = true;
        cfg.flush = policy;
        let mut w = build_world(&cfg);
        w.run_to_quiescence(60_000_000);
        invariants::assert_correct(&w.trace);
    });
}

/// The public codec round-trips every wire message, including
/// destination-coalesced `BATCH` frames (the codec unit tests cover the
/// nested/empty rejections; this drives the integration surface).
#[test]
fn codec_roundtrips_batched_and_plain_frames() {
    use wbam::codec::{decode, encode};
    use wbam::types::{MsgId, MsgMeta, Ts, Wire};
    prop::check(200, |r| {
        let n = r.range(1, 6) as usize;
        let inner: Vec<Wire> = (0..n)
            .map(|i| {
                let meta = MsgMeta::new(
                    MsgId::new(r.below(100) as u32, i as u32),
                    GidSet::single(Gid(r.below(10) as u32)),
                    (0..r.below(30) as usize).map(|_| r.below(256) as u8).collect(),
                );
                if r.chance(0.5) {
                    Wire::Multicast { meta }
                } else {
                    Wire::Delivered {
                        m: meta.id,
                        g: Gid(r.below(10) as u32),
                        gts: Ts::new(r.range(1, 1 << 30), Gid(r.below(10) as u32)),
                    }
                }
            })
            .collect();
        for w in &inner {
            assert_eq!(&decode(&encode(w)).expect("plain"), w);
        }
        let frame = Wire::Batch(inner);
        assert_eq!(decode(&encode(&frame)).expect("batch"), frame);
        // size estimate stays consistent with the 5-byte frame header
        let Wire::Batch(inner) = &frame else { unreachable!() };
        assert_eq!(frame.size(), 5 + inner.iter().map(|w| w.size()).sum::<usize>());
    });
}

/// Random wire generators shared by the codec-surface property tests
/// (`wire_size_bounds_encoded_length_for_every_variant` and the
/// transport frame-reassembly test below).
mod wire_gen {
    use wbam::types::wire::{MsgState, PaxosMsg, RsmCmd};
    use wbam::types::{Ballot, DeliveryPath, Gid, GidSet, MsgId, MsgMeta, Phase, Pid, Ts, Wire};
    use wbam::util::Rng;

    pub fn rnd_meta(r: &mut Rng) -> MsgMeta {
        let payload = (0..r.below(64) as usize).map(|_| r.below(256) as u8).collect();
        MsgMeta::new(MsgId::new(r.below(1000) as u32, r.below(1000) as u32), GidSet(r.next_u64()), payload)
    }
    pub fn rnd_ts(r: &mut Rng) -> Ts {
        Ts::new(r.below(1 << 40), Gid(r.below(64) as u32))
    }
    pub fn rnd_bal(r: &mut Rng) -> Ballot {
        Ballot::new(r.below(100) as u32, Pid(r.below(100) as u32))
    }
    pub fn rnd_state(r: &mut Rng) -> MsgState {
        let phase = *r.choose(&[Phase::Start, Phase::Proposed, Phase::Accepted, Phase::Committed]);
        MsgState { meta: rnd_meta(r), phase, lts: rnd_ts(r), gts: rnd_ts(r) }
    }
    pub fn rnd_cmd(r: &mut Rng) -> RsmCmd {
        if r.chance(0.5) {
            RsmCmd::AssignLts { meta: rnd_meta(r), lts: rnd_ts(r) }
        } else {
            RsmCmd::Commit { m: MsgId(r.next_u64()), gts: rnd_ts(r) }
        }
    }
    pub fn rnd_paxos(r: &mut Rng) -> PaxosMsg {
        match r.below(5) {
            0 => PaxosMsg::P1a { bal: rnd_bal(r) },
            1 => PaxosMsg::P1b {
                bal: rnd_bal(r),
                log: (0..r.below(4)).map(|i| (i, rnd_bal(r), rnd_cmd(r))).collect(),
            },
            2 => PaxosMsg::P2a { bal: rnd_bal(r), slot: r.next_u64(), cmd: rnd_cmd(r) },
            3 => PaxosMsg::P2b { bal: rnd_bal(r), slot: r.next_u64() },
            _ => PaxosMsg::Learn { slot: r.next_u64(), cmd: rnd_cmd(r) },
        }
    }
    /// A random wire of the given non-batch variant (0..14).
    pub fn wire_of_tag(tag: u64, r: &mut Rng) -> Wire {
        match tag {
            0 => Wire::Multicast { meta: rnd_meta(r) },
            1 => Wire::Delivered { m: MsgId(r.next_u64()), g: Gid(r.below(64) as u32), gts: rnd_ts(r) },
            2 => Wire::Propose { m: MsgId(r.next_u64()), g: Gid(r.below(64) as u32), lts: rnd_ts(r) },
            3 => Wire::Accept { meta: rnd_meta(r), g: Gid(r.below(64) as u32), bal: rnd_bal(r), lts: rnd_ts(r) },
            4 => Wire::AcceptAck {
                m: MsgId(r.next_u64()),
                g: Gid(r.below(64) as u32),
                bals: (0..r.below(5)).map(|i| (Gid(i as u32), rnd_bal(r))).collect(),
            },
            5 => Wire::Deliver {
                m: MsgId(r.next_u64()),
                bal: rnd_bal(r),
                lts: rnd_ts(r),
                gts: rnd_ts(r),
                path: DeliveryPath::from_u8(r.below(4) as u8),
            },
            6 => Wire::NewLeader { bal: rnd_bal(r) },
            7 => Wire::NewLeaderAck {
                bal: rnd_bal(r),
                cbal: rnd_bal(r),
                clock: r.next_u64(),
                state: (0..r.below(4)).map(|_| rnd_state(r)).collect(),
            },
            8 => Wire::NewState {
                bal: rnd_bal(r),
                clock: r.next_u64(),
                state: (0..r.below(4)).map(|_| rnd_state(r)).collect(),
            },
            9 => Wire::NewStateAck { bal: rnd_bal(r) },
            10 => Wire::Confirm { m: MsgId(r.next_u64()), g: Gid(r.below(64) as u32) },
            11 => Wire::Paxos { g: Gid(r.below(64) as u32), msg: rnd_paxos(r) },
            12 => Wire::Heartbeat { bal: rnd_bal(r) },
            _ => Wire::GcReport { max_gts: rnd_ts(r) },
        }
    }
}

/// `Wire::size()` must be an upper bound on the actual encoded length
/// for every variant, including nested `Batch` frames: the 8 MiB
/// `MAX_FRAME_BYTES` split uses the estimate to keep frames under the
/// TCP receiver's 64 MiB reject cap, so an under-estimate would let an
/// oversized frame through and kill the connection. The estimate must
/// also stay tight (small fixed slack per wire) to keep the simulator's
/// per-byte CPU/bandwidth model honest.
#[test]
fn wire_size_bounds_encoded_length_for_every_variant() {
    use wbam::codec::{decode, encode};
    use wbam::types::Wire;
    use wire_gen::wire_of_tag;

    // per-wire slack the estimate may leave over the true encoding; 0
    // today (the estimate mirrors the codec), but the property only
    // demands "upper bound, within a small fixed slack per message"
    const SLACK_PER_WIRE: usize = 8;

    prop::check(300, |r| {
        // every leaf variant exercised in every case
        for tag in 0..14u64 {
            let w = wire_of_tag(tag, r);
            let enc = encode(&w);
            assert!(
                enc.len() <= w.size(),
                "size() under-estimates {}: encoded {} > size {}",
                w.tag(),
                enc.len(),
                w.size()
            );
            assert!(
                w.size() <= enc.len() + SLACK_PER_WIRE,
                "size() over-estimates {}: size {} >> encoded {}",
                w.tag(),
                w.size(),
                enc.len()
            );
            assert_eq!(decode(&enc).expect("roundtrip"), w);
        }
        // nested batch: the frame estimate bounds the encoded frame too
        let inner: Vec<Wire> = (0..r.range(1, 6)).map(|_| wire_of_tag(r.below(14), r)).collect();
        let n = inner.len();
        let frame = Wire::Batch(inner);
        let enc = encode(&frame);
        assert!(enc.len() <= frame.size(), "batch under-estimated: {} > {}", enc.len(), frame.size());
        assert!(frame.size() <= enc.len() + SLACK_PER_WIRE * (n + 1), "batch over-estimated");
        assert_eq!(decode(&enc).expect("batch roundtrip"), frame);
    });
}

/// The epoll transport's partial-frame reassembly: a valid length-
/// prefixed frame stream chopped at arbitrary byte boundaries must
/// reassemble to exactly the original `(from, to, wire)` sequence —
/// every frame whole, in order, nothing left over. This is the
/// receive-path contract nonblocking reads depend on (a read returns
/// whatever the socket has, so frames routinely split mid-header and
/// mid-payload); generators shared with the codec size-bound test.
#[test]
fn frame_reassembly_survives_arbitrary_split_points() {
    use wbam::codec;
    use wbam::net::FrameAssembler;
    use wbam::types::Wire;

    prop::check(150, |r| {
        // a random frame stream: plain wires and coalesced batches, with
        // random link endpoints, encoded exactly as the socket transports
        // frame them (u32 len ++ u32 from ++ u32 to ++ codec bytes)
        let mut frames: Vec<(Pid, Pid, Wire)> = Vec::new();
        let mut stream: Vec<u8> = Vec::new();
        let mut e = codec::Enc::new();
        for _ in 0..r.range(1, 8) {
            let wire = if r.chance(0.3) {
                Wire::Batch((0..r.range(1, 4)).map(|_| wire_gen::wire_of_tag(r.below(14), r)).collect())
            } else {
                wire_gen::wire_of_tag(r.below(14), r)
            };
            let from = Pid(r.below(100) as u32);
            let to = Pid(r.below(100) as u32);
            wbam::net::encode_frame(&mut e, from, to, &wire);
            stream.extend_from_slice(&e.buf);
            frames.push((from, to, wire));
        }
        // feed the stream in random-sized chunks (1..40 bytes)
        let mut asm = FrameAssembler::new();
        let mut got: Vec<(Pid, Pid, Wire)> = Vec::new();
        let mut pos = 0usize;
        while pos < stream.len() {
            let take = (r.range(1, 40) as usize).min(stream.len() - pos);
            asm.push(&stream[pos..pos + take], &mut |f, t, w| got.push((f, t, w))).expect("valid stream");
            pos += take;
        }
        assert_eq!(asm.pending(), 0, "bytes left unconsumed after the final frame");
        assert_eq!(got, frames, "reassembled frames diverged from the sent stream");
    });
}

/// The zero-copy payload path is invisible on the wire and lossless off
/// it: over arbitrary `Wire::Batch` frames (and every leaf variant),
/// `decode_shared` agrees with the copying `decode`, re-encoding an
/// `Arc`-backed wire is byte-identical to the original PR 1 codec
/// output, and every non-empty decoded payload is a view into the one
/// shared frame buffer — no per-payload allocation.
#[test]
fn zero_copy_decode_matches_copying_codec_byte_for_byte() {
    use std::sync::Arc;
    use wbam::codec::{decode, decode_shared, encode};
    use wbam::types::{Payload, Wire};
    use wire_gen::wire_of_tag;

    /// Every payload a wire carries, batches and recovery state included.
    fn payloads<'a>(w: &'a Wire, out: &mut Vec<&'a Payload>) {
        match w {
            Wire::Multicast { meta } => out.push(&meta.payload),
            Wire::Accept { meta, .. } => out.push(&meta.payload),
            Wire::NewLeaderAck { state, .. } | Wire::NewState { state, .. } => {
                out.extend(state.iter().map(|s| &s.meta.payload));
            }
            Wire::Batch(inner) => {
                for iw in inner {
                    payloads(iw, out);
                }
            }
            _ => {}
        }
    }

    prop::check(250, |r| {
        // a batch of random payload-heavy leaves, or a lone leaf
        let frame = if r.chance(0.7) {
            Wire::Batch((0..r.range(1, 6)).map(|_| wire_of_tag(r.below(14), r)).collect())
        } else {
            wire_of_tag(r.below(14), r)
        };
        let bytes = encode(&frame);
        // the copying decoder is the PR 1 baseline
        assert_eq!(decode(&bytes).expect("copying decode"), frame);
        // the shared decoder agrees with it structurally…
        let arc: Arc<[u8]> = bytes.clone().into();
        let shared = decode_shared(&arc, 0, arc.len()).expect("shared decode");
        assert_eq!(shared, frame, "shared decode diverged from the copying codec");
        // …its payloads are views into the single frame buffer…
        let whole = Payload::view(Arc::clone(&arc), 0, arc.len());
        let mut views = Vec::new();
        payloads(&shared, &mut views);
        for p in views.iter().filter(|p| !p.as_slice().is_empty()) {
            assert!(p.shares_buffer_with(&whole), "non-empty payload was copied, not shared");
            assert_eq!(p.backing_len(), arc.len());
        }
        // …and re-encoding the Arc-backed wire is byte-identical
        assert_eq!(encode(&shared), bytes, "encode over shared payloads changed the wire format");
    });
}

/// Two successive leader crashes in different groups: the system keeps
/// converging (probing ballot monotonicity, Invariants 8/9, externally).
#[test]
fn repeated_recoveries_converge() {
    prop::check(8, |r| {
        let delta = MS;
        let mut cfg = RunCfg::new(Proto::WbCast, 2, 3, 2, Net::Theory { delta });
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(12);
        cfg.record_full = true;
        cfg.wb = WbConfig::with_failures(delta);
        cfg.resend_after = 40 * delta;
        let mut w = build_world(&cfg);
        w.crash_at(Pid(0), r.range(5, 40) * delta);
        w.crash_at(Pid(3), r.range(50, 90) * delta);
        w.run_until(6_000 * delta);
        invariants::assert_safe(&w.trace);
        let vs = invariants::check_termination(&w.trace);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(w.trace.incomplete(), 0, "stuck messages");
    });
}

// ---------------------------------------------------------------------
// Durable storage codec (tentpole PR 4): record round-trips, CRC
// rejection of arbitrary corruption, and torn-tail recovery over
// arbitrary cut points — in memory and through the file-backed WAL.
// ---------------------------------------------------------------------

mod storage_props {
    use wbam::storage::{
        append_frame, decode_frames, decode_record, encode_record, MemWal, Record, Snapshot,
        Storage, SyncPolicy, WalFault,
    };
    use wbam::types::wire::MsgState;
    use wbam::types::{Ballot, Gid, GidSet, MsgId, MsgMeta, Phase, Pid, Ts};
    use wbam::util::{prop, Rng};

    fn rand_ts(r: &mut Rng) -> Ts {
        if r.chance(0.1) {
            Ts::BOT
        } else {
            Ts::new(r.range(1, 1 << 40), Gid(r.below(64) as u32))
        }
    }
    fn rand_ballot(r: &mut Rng) -> Ballot {
        if r.chance(0.1) {
            Ballot::BOT
        } else {
            Ballot::new(r.range(1, 1000) as u32, Pid(r.below(100) as u32))
        }
    }
    fn rand_state(r: &mut Rng) -> MsgState {
        let n = r.below(30) as usize;
        MsgState {
            meta: MsgMeta {
                id: MsgId(r.next_u64()),
                dest: GidSet(r.next_u64() & 0x3FF),
                payload: (0..n).map(|_| r.below(256) as u8).collect::<Vec<u8>>().into(),
                submit_ns: r.next_u64(),
            },
            phase: *r.choose(&[Phase::Start, Phase::Proposed, Phase::Accepted, Phase::Committed]),
            lts: rand_ts(r),
            gts: rand_ts(r),
        }
    }
    fn rand_record(r: &mut Rng) -> Record {
        match r.below(5) {
            0 => Record::Promote { ballot: rand_ballot(r), cballot: rand_ballot(r), clock: r.next_u64() },
            1 => Record::State { state: rand_state(r), clock: r.next_u64() },
            2 => Record::Deliver { m: MsgId(r.next_u64()), lts: rand_ts(r), gts: rand_ts(r) },
            3 => Record::Adopt {
                ballot: rand_ballot(r),
                cballot: rand_ballot(r),
                clock: r.next_u64(),
                state: (0..r.below(4)).map(|_| rand_state(r)).collect(),
            },
            _ => Record::Trim { wm: rand_ts(r) },
        }
    }

    /// Every record round-trips through the payload codec and the framed
    /// log representation.
    #[test]
    fn storage_records_roundtrip_random() {
        prop::check(200, |r| {
            let recs: Vec<Record> = (0..r.range(1, 12)).map(|_| rand_record(r)).collect();
            let mut buf = Vec::new();
            for rec in &recs {
                assert_eq!(decode_record(&encode_record(rec)).expect("payload roundtrip"), *rec);
                append_frame(&mut buf, rec);
            }
            let (got, used) = decode_frames(&buf);
            assert_eq!(got, recs);
            assert_eq!(used, buf.len());
        });
    }

    /// Flipping ANY single byte of the framed log is caught: replay
    /// returns exactly the records before the corrupted frame — never a
    /// mangled record, never a panic.
    #[test]
    fn storage_crc_rejects_any_corrupted_byte() {
        prop::check(200, |r| {
            let recs: Vec<Record> = (0..r.range(2, 10)).map(|_| rand_record(r)).collect();
            let mut buf = Vec::new();
            let mut ends = Vec::new(); // cumulative end offset of each frame
            for rec in &recs {
                append_frame(&mut buf, rec);
                ends.push(buf.len());
            }
            let victim = r.below(buf.len() as u64) as usize;
            let hit = ends.iter().position(|&e| victim < e).expect("offset inside a frame");
            let mut bad = buf.clone();
            bad[victim] ^= (r.range(1, 255)) as u8; // any non-zero flip
            let (got, used) = decode_frames(&bad);
            assert_eq!(got, recs[..hit], "corruption in frame {hit} must stop replay there");
            assert_eq!(used, if hit == 0 { 0 } else { ends[hit - 1] });
        });
    }

    /// Cutting the framed log at an arbitrary byte (a torn tail from a
    /// crash mid-write) recovers exactly the longest whole-frame prefix.
    #[test]
    fn storage_truncated_tail_recovers_longest_valid_prefix() {
        prop::check(200, |r| {
            let recs: Vec<Record> = (0..r.range(1, 10)).map(|_| rand_record(r)).collect();
            let mut buf = Vec::new();
            let mut ends = Vec::new();
            for rec in &recs {
                append_frame(&mut buf, rec);
                ends.push(buf.len());
            }
            let cut = r.below(buf.len() as u64 + 1) as usize;
            let whole = ends.iter().filter(|&&e| e <= cut).count();
            let (got, used) = decode_frames(&buf[..cut]);
            assert_eq!(got, recs[..whole], "cut at {cut} must recover the {whole}-frame prefix");
            assert_eq!(used, if whole == 0 { 0 } else { ends[whole - 1] });
        });
    }

    /// The file-backed WAL agrees with the in-memory model under random
    /// records + a random torn tail: reopening replays the whole-frame
    /// prefix, truncates the garbage, and folds the same [`Snapshot`].
    #[test]
    fn storage_file_wal_replays_random_torn_tails() {
        prop::check(20, |r| {
            let seed_tag = r.next_u64();
            let dir = std::env::temp_dir().join(format!("wbam-prop-wal-{}-{seed_tag:x}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            let recs: Vec<Record> = (0..r.range(1, 20)).map(|_| rand_record(r)).collect();
            let mut frames = Vec::new();
            let mut ends = Vec::new();
            for rec in &recs {
                append_frame(&mut frames, rec);
                ends.push(frames.len());
            }
            {
                let mut s = Storage::open(&dir, SyncPolicy::Never).expect("open");
                for rec in &recs {
                    s.append(rec).expect("append");
                }
                s.sync().expect("sync");
            }
            // tear the active segment at a random byte length
            let seg = dir.join(format!("wal-{:016x}.log", 0));
            let cut = r.below(frames.len() as u64 + 1) as usize;
            let f = std::fs::OpenOptions::new().write(true).open(&seg).expect("segment");
            f.set_len(cut as u64).expect("truncate");
            drop(f);
            let whole = ends.iter().filter(|&&e| e <= cut).count();
            let mut want = Snapshot::default();
            for rec in &recs[..whole] {
                want.apply(rec);
            }
            let s = Storage::open(&dir, SyncPolicy::Never).expect("torn reopen");
            assert_eq!(*s.image(), want, "file replay diverged at cut {cut} ({whole} whole frames)");
            assert_eq!(s.record_count(), whole as u64);
            drop(s);
            let _ = std::fs::remove_dir_all(&dir);
        });
    }

    // ----- MemWal nemesis faults (tentpole PR 10): torn + failing writes -----

    /// A torn write at any armed cut point leaves a strict prefix of the
    /// frame, the tear is observable before anything else can happen, and
    /// recovery folds exactly the records that were durable *before* the
    /// torn append — never a mangled record, never the torn one.
    #[test]
    fn memwal_torn_write_recovers_pre_tear_prefix() {
        prop::check(200, |r| {
            let mut wal = MemWal::new();
            let before: Vec<Record> = (0..r.below(8)).map(|_| rand_record(r)).collect();
            for rec in &before {
                wal.append(rec);
            }
            let durable = wal.bytes().len();
            wal.arm_fault(WalFault::Torn, r.below(10_000) as u32);
            wal.append(&rand_record(r));
            assert_eq!(wal.take_fired(), Some(WalFault::Torn));
            assert!(wal.bytes().len() >= durable, "tear must not eat durable frames");
            assert_eq!(wal.len(), before.len() as u64, "torn record must not count");
            assert!(!wal.is_poisoned(), "a tear is a crash, not a poison");
            let mut want = Snapshot::default();
            for rec in &before {
                want.apply(rec);
            }
            assert_eq!(wal.recover(), want, "recovery must stop at the tear");
            // after the crash-observation, journaling works again
            let extra = rand_record(r);
            wal.truncate_to(durable); // restart replays the valid prefix
            wal.append(&extra);
            want.apply(&extra);
            assert_eq!(wal.recover(), want);
        });
    }

    /// A failed write keeps nothing, poisons the log before any caller
    /// could acknowledge, and every later append is silently discarded —
    /// the `POISONED`-marker semantics of the file-backed [`Storage`].
    #[test]
    fn memwal_failed_write_poisons_before_any_ack() {
        prop::check(200, |r| {
            let mut wal = MemWal::new();
            let before: Vec<Record> = (0..r.below(6)).map(|_| rand_record(r)).collect();
            for rec in &before {
                wal.append(rec);
            }
            let durable = wal.bytes().to_vec();
            wal.arm_fault(WalFault::Failed, 0);
            wal.append(&rand_record(r));
            // poison is visible BEFORE the fault is even taken: no window
            // in which an ack could slip out against a lost write
            assert!(wal.is_poisoned());
            assert_eq!(wal.bytes(), &durable[..], "failed write must write nothing");
            assert_eq!(wal.take_fired(), Some(WalFault::Failed));
            for _ in 0..r.range(1, 5) {
                wal.append(&rand_record(r));
            }
            assert_eq!(wal.bytes(), &durable[..], "post-poison appends must be discarded");
            assert_eq!(wal.len(), before.len() as u64);
            let mut want = Snapshot::default();
            for rec in &before {
                want.apply(rec);
            }
            assert_eq!(wal.recover(), want);
        });
    }

    /// While a tear is fired-but-unobserved nothing else lands: a
    /// multi-record flush whose first frame tears ends the write stream
    /// at the tear, exactly like a real crash mid-write.
    #[test]
    fn memwal_unobserved_tear_blocks_followup_appends() {
        prop::check(100, |r| {
            let mut wal = MemWal::new();
            wal.arm_fault(WalFault::Torn, r.below(10_000) as u32);
            wal.append(&rand_record(r));
            let torn_len = wal.bytes().len();
            for _ in 0..r.range(1, 4) {
                wal.append(&rand_record(r)); // same flush, tear not yet taken
            }
            assert_eq!(wal.bytes().len(), torn_len, "appends after an unobserved tear must not land");
            assert_eq!(wal.len(), 0);
            assert_eq!(wal.take_fired(), Some(WalFault::Torn));
            assert_eq!(wal.recover(), Snapshot::default());
        });
    }
}
