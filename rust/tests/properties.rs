//! Randomized property tests: safety (Validity, Integrity, Ordering —
//! the observable consequences of Invariants 1–5) and Termination over
//! randomly generated deployments, workloads, schedules and failure
//! patterns. Failing cases report a replay seed.

use wbam::harness::{build_world, Net, Proto, RunCfg};
use wbam::invariants;
use wbam::protocols::wbcast::WbConfig;
use wbam::sim::MS;
use wbam::types::{Gid, GidSet, Pid};
use wbam::util::prop;

/// Random failure-free runs across all four protocols, LAN jitter.
#[test]
fn safety_and_termination_random_failure_free() {
    prop::check(25, |r| {
        let proto = *r.choose(&Proto::ALL);
        let groups = r.range(1, 4) as usize;
        let clients = r.range(1, 6) as usize;
        let dest = r.range(1, groups as u64) as usize;
        let mut cfg = RunCfg::new(proto, groups, clients, dest, Net::Lan);
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(r.range(3, 25) as u32);
        cfg.record_full = true;
        let mut w = build_world(&cfg);
        w.run_to_quiescence(60_000_000);
        invariants::assert_correct(&w.trace);
    });
}

/// Random WAN runs (large heterogeneous delays stress cross-group
/// reordering).
#[test]
fn safety_random_wan() {
    prop::check(10, |r| {
        let proto = *r.choose(&Proto::EVAL);
        let groups = r.range(2, 5) as usize;
        let mut cfg = RunCfg::new(proto, groups, 4, 2, Net::Wan);
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(8);
        cfg.record_full = true;
        let mut w = build_world(&cfg);
        w.run_to_quiescence(30_000_000);
        invariants::assert_correct(&w.trace);
    });
}

/// WbCast with random single-crash injection (≤ f per group): safety
/// always; termination among correct processes.
#[test]
fn wbcast_random_crashes() {
    prop::check(15, |r| {
        let delta = MS;
        let groups = r.range(2, 3) as usize;
        let mut cfg = RunCfg::new(Proto::WbCast, groups, 3, 2, Net::Theory { delta });
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(15);
        cfg.record_full = true;
        cfg.wb = WbConfig::with_failures(delta);
        cfg.resend_after = 40 * delta;
        let mut w = build_world(&cfg);
        // crash one random member (possibly a leader) at a random time
        let victim = Pid(r.below((groups * 3) as u64) as u32);
        let when = r.range(1, 60) * delta;
        w.crash_at(victim, when);
        w.run_until(4_000 * delta);
        invariants::assert_safe(&w.trace);
        let vs = invariants::check_termination(&w.trace);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(w.trace.incomplete(), 0, "stuck messages");
    });
}

/// WbCast with aggressive client retransmissions (duplicates everywhere)
/// must not double-deliver or reorder.
#[test]
fn wbcast_duplicate_storms() {
    prop::check(15, |r| {
        let delta = MS;
        let mut cfg = RunCfg::new(Proto::WbCast, 3, 4, 2, Net::Theory { delta });
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(10);
        cfg.record_full = true;
        // resend faster than the 3δ commit latency → constant duplicates
        cfg.resend_after = r.range(1, 3) * delta;
        let mut w = build_world(&cfg);
        w.run_to_quiescence(60_000_000);
        invariants::assert_correct(&w.trace);
    });
}

/// Genuineness (§II minimality): processes outside dest(m) ∪ {sender}
/// receive no protocol traffic when every multicast avoids their groups.
#[test]
fn genuineness_non_destinations_stay_silent() {
    for proto in Proto::EVAL {
        let topo = wbam::types::Topology::new(4, 1);
        let mut nodes: Vec<Box<dyn wbam::protocols::Node>> = Vec::new();
        for g in topo.gids() {
            for &p in topo.members(g) {
                match proto {
                    Proto::FtSkeen => nodes.push(Box::new(wbam::protocols::ftskeen::FtSkeenNode::new(p, topo.clone()))),
                    Proto::FastCast => nodes.push(Box::new(wbam::protocols::fastcast::FastCastNode::new(p, topo.clone()))),
                    _ => nodes.push(Box::new(wbam::protocols::wbcast::WbNode::new(p, topo.clone(), WbConfig::default()))),
                }
            }
        }
        let both = GidSet::from_iter([Gid(0), Gid(1)]);
        let script: Vec<(u64, GidSet)> = (0..10).map(|i| (i * MS, both)).collect();
        nodes.push(Box::new(wbam::harness::ScriptedClient::new(topo.first_client_pid(), topo.clone(), script)));
        let mut w = wbam::sim::World::new(topo.clone(), nodes, wbam::sim::SimConfig::theory(MS));
        w.run_to_quiescence(1_000_000);
        invariants::assert_safe(&w.trace);
        // members of g2 and g3 never participate
        for g in [Gid(2), Gid(3)] {
            for &p in topo.members(g) {
                let n = w.arrivals.get(&p).copied().unwrap_or(0);
                assert_eq!(n, 0, "{}: non-destination {p:?} received {n} messages", proto.name());
            }
        }
    }
}

/// Deterministic replay: identical seeds produce identical traces.
#[test]
fn simulation_is_deterministic() {
    prop::check(5, |r| {
        let seed = r.next_u64();
        let mk = || {
            let mut cfg = RunCfg::new(Proto::WbCast, 3, 4, 2, Net::Lan);
            cfg.seed = seed;
            cfg.max_requests = Some(20);
            cfg.record_full = true;
            let mut w = build_world(&cfg);
            w.run_to_quiescence(30_000_000);
            (w.trace.sends, w.trace.delivered_count, w.trace.mean_latency())
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
    });
}

/// Wire batching is schedule-transparent in the constant-δ, zero-CPU
/// setting: the same `(nodes, config, seed)` with destination coalescing
/// on vs off must produce identical per-process delivery orders (frames
/// only merge same-destination sends of one event, whose inner FIFO
/// order the batch preserves), and the invariant checker must be green
/// in both. Covers commit staging both off (`batch_threshold = 1`) and
/// on (8), which is what pumps multi-wire frames through `DELIVER`
/// fan-out.
#[test]
fn batching_preserves_delivery_order() {
    for &seed in &[3u64, 0x5EED, 0xB47C4] {
        for &threshold in &[1usize, 8] {
            let run_one = |coalesce: bool| {
                let mut cfg = RunCfg::new(Proto::WbCast, 3, 4, 2, Net::Theory { delta: MS });
                cfg.seed = seed;
                cfg.max_requests = Some(25);
                cfg.record_full = true;
                cfg.coalesce = coalesce;
                cfg.wb = WbConfig { batch_threshold: threshold, batch_flush_after: 5 * MS, ..WbConfig::default() };
                let mut w = build_world(&cfg);
                w.run_to_quiescence(60_000_000);
                invariants::assert_correct(&w.trace);
                // per-process delivery sequence: (pid, message, gts)
                let mut per_pid: std::collections::BTreeMap<Pid, Vec<_>> = Default::default();
                for d in &w.trace.deliveries {
                    per_pid.entry(d.pid).or_default().push((d.m, d.gts));
                }
                per_pid
            };
            let batched = run_one(true);
            let unbatched = run_one(false);
            assert_eq!(
                batched, unbatched,
                "delivery orders diverged between coalesce on/off (seed {seed:#x}, batch_threshold {threshold})"
            );
        }
    }
}

/// The public codec round-trips every wire message, including
/// destination-coalesced `BATCH` frames (the codec unit tests cover the
/// nested/empty rejections; this drives the integration surface).
#[test]
fn codec_roundtrips_batched_and_plain_frames() {
    use wbam::codec::{decode, encode};
    use wbam::types::{MsgId, MsgMeta, Ts, Wire};
    prop::check(200, |r| {
        let n = r.range(1, 6) as usize;
        let inner: Vec<Wire> = (0..n)
            .map(|i| {
                let meta = MsgMeta::new(
                    MsgId::new(r.below(100) as u32, i as u32),
                    GidSet::single(Gid(r.below(10) as u32)),
                    (0..r.below(30) as usize).map(|_| r.below(256) as u8).collect(),
                );
                if r.chance(0.5) {
                    Wire::Multicast { meta }
                } else {
                    Wire::Delivered {
                        m: meta.id,
                        g: Gid(r.below(10) as u32),
                        gts: Ts::new(r.range(1, 1 << 30), Gid(r.below(10) as u32)),
                    }
                }
            })
            .collect();
        for w in &inner {
            assert_eq!(&decode(&encode(w)).expect("plain"), w);
        }
        let frame = Wire::Batch(inner);
        assert_eq!(decode(&encode(&frame)).expect("batch"), frame);
        // size estimate stays consistent with the 5-byte frame header
        let Wire::Batch(inner) = &frame else { unreachable!() };
        assert_eq!(frame.size(), 5 + inner.iter().map(|w| w.size()).sum::<usize>());
    });
}

/// Two successive leader crashes in different groups: the system keeps
/// converging (probing ballot monotonicity, Invariants 8/9, externally).
#[test]
fn repeated_recoveries_converge() {
    prop::check(8, |r| {
        let delta = MS;
        let mut cfg = RunCfg::new(Proto::WbCast, 2, 3, 2, Net::Theory { delta });
        cfg.seed = r.next_u64();
        cfg.max_requests = Some(12);
        cfg.record_full = true;
        cfg.wb = WbConfig::with_failures(delta);
        cfg.resend_after = 40 * delta;
        let mut w = build_world(&cfg);
        w.crash_at(Pid(0), r.range(5, 40) * delta);
        w.crash_at(Pid(3), r.range(50, 90) * delta);
        w.run_until(6_000 * delta);
        invariants::assert_safe(&w.trace);
        let vs = invariants::check_termination(&w.trace);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(w.trace.incomplete(), 0, "stuck messages");
    });
}
