//! Swarm entry point: the nemesis fault-injection campaign as a test
//! binary (`cargo test --test swarm`), plus the pins that make the
//! campaign trustworthy — determinism (a seed IS a reproducer), the
//! zero-perturbation identity (nemesis wiring adds nothing to a
//! fault-free run), and the fire drill (an injected safety bug is
//! caught, JSON-round-tripped, replayed and minimized).
//!
//! `cargo xtask swarm` drives the same `wbam::sim::swarm` library at
//! campaign scale with on-disk artifacts; these tests keep the library
//! honest on every PR.

use wbam::harness::{build_world, enable_wb_storage, Net, Proto, RunCfg};
use wbam::protocols::wbcast::WbConfig;
use wbam::sim::nemesis::{NemesisEvent, NemesisSchedule, Shim};
use wbam::sim::swarm;
use wbam::sim::MS;
use wbam::types::{Pid, Topology};

/// A fixed-shape, zero-fault schedule (the identity-pin baseline).
fn plain_schedule(seed: u64) -> NemesisSchedule {
    NemesisSchedule {
        seed,
        groups: 2,
        clients: 3,
        dest_groups: 2,
        reqs: 3,
        delta: MS,
        horizon: 2_600 * MS,
        shim: None,
        events: Vec::new(),
    }
}

/// Determinism pin: the same schedule run twice produces byte-identical
/// traces — equal delivery streams (time, pid, message, gts in order)
/// and equal digests — for generated schedules across many seeds.
#[test]
fn same_seed_same_trace() {
    for seed in [1u64, 7, 42, 0xDEAD_BEEF, u64::MAX] {
        let s = NemesisSchedule::generate(seed);
        let mut a = swarm::build(&s);
        let mut b = swarm::build(&s);
        a.run_until(s.horizon);
        b.run_until(s.horizon);
        assert_eq!(a.trace.deliveries, b.trace.deliveries, "seed {seed}: delivery streams differ");
        assert_eq!(a.trace.crashes, b.trace.crashes, "seed {seed}: crash sets differ");
        assert_eq!(a.trace.restarts, b.trace.restarts, "seed {seed}: restart sets differ");
        assert_eq!(a.trace.sends, b.trace.sends, "seed {seed}: send counts differ");
        assert_eq!(a.trace.digest(), b.trace.digest(), "seed {seed}: digests differ");
    }
}

/// Zero-perturbation pin: a fault-free [`NemesisSchedule`] run is
/// event-for-event identical to the plain sim run it describes — the
/// nemesis machinery (fault tables, knob plumbing, flight recorder)
/// consumes no randomness and shifts no event when no fault is active.
#[test]
fn zero_fault_schedule_is_identity() {
    let s = plain_schedule(4242);

    // the plain run: built by hand, no nemesis wiring touched
    let delta = s.delta;
    let mut cfg = RunCfg::new(Proto::WbCast, s.groups, s.clients, s.dest_groups, Net::Theory { delta });
    cfg.seed = s.seed;
    cfg.max_requests = Some(s.reqs);
    cfg.record_full = true;
    cfg.resend_after = 40 * delta;
    let mut wb = WbConfig::with_failures(delta);
    wb.durability = true;
    cfg.wb = wb;
    let mut plain = build_world(&cfg);
    enable_wb_storage(&mut plain, &Topology::new(s.groups, 1), wb);
    plain.run_until(s.horizon);

    let mut nem = swarm::build(&s);
    nem.run_until(s.horizon);

    assert_eq!(plain.trace.deliveries, nem.trace.deliveries, "delivery streams diverged");
    assert_eq!(plain.trace.sends, nem.trace.sends, "send counts diverged");
    assert_eq!(plain.trace.send_bytes, nem.trace.send_bytes, "send bytes diverged");
    assert_eq!(plain.trace.latencies, nem.trace.latencies, "latency samples diverged");
    assert_eq!(plain.trace.digest(), nem.trace.digest(), "trace digests diverged");
    assert_eq!(plain.trace.incomplete(), 0, "baseline run left messages stuck");
}

/// Campaign smoke: a batch of generated schedules all pass the strict
/// invariant suite, and two identical campaigns produce the identical
/// summary hash (the `xtask swarm` acceptance pin, in miniature).
/// `WBAM_SMOKE=1` halves the batch for the PR gate.
#[test]
fn campaign_smoke_is_green_and_deterministic() {
    let n = if std::env::var("WBAM_SMOKE").is_ok() { 8 } else { 16 };
    let c1 = swarm::campaign(n, 1);
    for f in &c1.failures {
        panic!(
            "schedule {} (seed {}) failed: {:?}\nschedule JSON:\n{}",
            f.index,
            f.schedule.seed,
            f.outcome.violations,
            f.schedule.to_json()
        );
    }
    let c2 = swarm::campaign(n, 1);
    assert_eq!(c1.summary, c2.summary, "campaign summary hash is not reproducible");
    assert_ne!(c1.summary, swarm::campaign(n, 2).summary, "summary hash ignores the seed");
}

/// Fire drill + reproducer round-trip: a schedule carrying the
/// double-deliver shim must (1) fail the integrity check with the
/// flight recorder armed and non-empty, (2) round-trip through JSON to
/// the same failure — digest and all, (3) minimize to ≤ 25 % of the
/// original fault events while still failing.
#[test]
fn injected_violation_is_caught_reproduced_and_minimized() {
    // a real generated fault plan around the seeded bug, so the
    // minimizer has something to strip away
    let mut s = NemesisSchedule::generate(99);
    assert!(s.events.len() >= 4, "generator should emit >= 4 events");
    s.shim = Some(Shim::DoubleDeliver { pid: Pid(1), nth: 3 });

    let o = swarm::run(&s);
    assert!(o.failed(), "double-deliver shim must trip the checkers");
    assert!(
        o.violations.iter().any(|v| v.contains("integrity")),
        "expected an integrity violation, got {:?}",
        o.violations
    );
    assert!(!o.flight.is_empty(), "flight recorder must capture the failing run");

    // JSON round-trip: parse(json(s)) replays to the SAME failure
    let json = s.to_json();
    let parsed = NemesisSchedule::from_json(&json).expect("schedule JSON must parse");
    assert_eq!(parsed, s, "JSON round-trip must be lossless");
    let o2 = swarm::run(&parsed);
    assert_eq!(o2.violations, o.violations, "replay must reproduce the same violations");
    assert_eq!(o2.digest, o.digest, "replay must reproduce the same trace digest");

    // ddmin: the schedule shrinks to <= 25 % of its events and the
    // minimized schedule still fails and still round-trips
    let min = swarm::minimize(&s);
    assert!(
        min.events.len() * 4 <= s.events.len(),
        "minimizer left {} of {} events (> 25 %)",
        min.events.len(),
        s.events.len()
    );
    assert!(swarm::run(&min).failed(), "minimized schedule must still fail");
    let min2 = NemesisSchedule::from_json(&min.to_json()).expect("minimized JSON must parse");
    assert_eq!(min2, min);
}

/// A failing disk write crashes the process inside the same atomic
/// event — before any acknowledgement ships — poisons its WAL so the
/// restart is refused, and the rest of the group (f = 1) finishes every
/// multicast under the strict checks.
#[test]
fn disk_fail_crashes_before_ack_and_refuses_restart() {
    let mut s = plain_schedule(7);
    s.events = vec![
        NemesisEvent::DiskFail { at: 5 * MS, pid: Pid(2) },
        NemesisEvent::Restart { at: 200 * MS, pid: Pid(2) },
    ];
    let mut w = swarm::build(&s);
    w.run_until(s.horizon);

    assert!(
        w.trace.crashes.iter().any(|&(_, p)| p == Pid(2)),
        "the failed write must crash Pid(2): {:?}",
        w.trace.crashes
    );
    assert!(w.is_crashed(Pid(2)), "poisoned store must refuse the restart");
    assert!(w.trace.restarts.iter().all(|&(_, p)| p != Pid(2)));
    assert!(w.store(Pid(2)).expect("storage enabled").is_poisoned());

    // and the run as a whole is still correct: the crash is permanent
    // but within the f = 1 budget
    let vs = wbam::invariants::check_correct(&w.trace);
    assert!(vs.is_empty(), "{vs:?}");
    assert_eq!(w.trace.incomplete(), 0, "group must finish without the poisoned member");
}

/// A torn disk write recovers on restart through the torn-tail codec:
/// the process rejoins from the longest whole-frame prefix and the run
/// ends correct and complete.
#[test]
fn disk_torn_recovers_through_restart() {
    let mut s = plain_schedule(11);
    s.events = vec![
        NemesisEvent::DiskTorn { at: 5 * MS, pid: Pid(1), cut_bp: 5_000 },
        NemesisEvent::Restart { at: 300 * MS, pid: Pid(1) },
    ];
    let o = swarm::run(&s);
    assert!(!o.failed(), "torn-write crash + recovery must stay correct: {:?}", o.violations);

    let mut w = swarm::build(&s);
    w.run_until(s.horizon);
    assert!(
        w.trace.restarts.iter().any(|&(_, p)| p == Pid(1)),
        "Pid(1) must restart from the torn log's valid prefix"
    );
    assert!(!w.is_crashed(Pid(1)));
}
