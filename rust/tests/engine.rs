//! Integration: the XLA batch commit engine (AOT JAX/Pallas artifacts)
//! against the native oracle, across randomized batches.
//!
//! Requires `make artifacts` and a build with `--features xla` (the
//! offline default build ships the native-fallback stub instead).
#![cfg(feature = "xla")]

use wbam::runtime::{commit_batch_native, BatchReq, CommitBatchEngine, QuantileEngine};
use wbam::types::{Gid, MsgId, Ts};
use wbam::util::{prop, Rng};

fn engine() -> CommitBatchEngine {
    let dir = wbam::runtime::engine::artifacts_dir();
    CommitBatchEngine::load(&dir).expect("artifacts missing — run `make artifacts`")
}

fn rand_ts(r: &mut Rng) -> Ts {
    Ts::new(r.range(1, 1 << 30), Gid(r.below(16) as u32))
}

#[test]
fn engine_matches_native_on_random_batches() {
    let eng = engine();
    prop::check(40, |r| {
        let n = r.range(1, 40) as usize;
        let reqs: Vec<BatchReq> = (0..n)
            .map(|i| {
                let groups = r.range(1, 10) as usize;
                BatchReq { m: MsgId::new(1, i as u32), lts: (0..groups).map(|_| rand_ts(r)).collect() }
            })
            .collect();
        let np = r.below(60) as usize;
        let pending: Vec<Ts> = (0..np).map(|_| rand_ts(r)).collect();
        let want = commit_batch_native(&reqs, &pending);
        let got = eng.commit_batch(&reqs, &pending).expect("engine");
        assert_eq!(got, want);
    });
}

#[test]
fn engine_chunks_oversized_batches() {
    let eng = engine();
    let n = eng.max_batch() * 2 + 7;
    let reqs: Vec<BatchReq> = (0..n)
        .map(|i| BatchReq { m: MsgId::new(2, i as u32), lts: vec![Ts::new(i as u64 + 1, Gid(0))] })
        .collect();
    let got = eng.commit_batch(&reqs, &[]).unwrap();
    assert_eq!(got.len(), n);
    for (i, o) in got.iter().enumerate() {
        assert_eq!(o.gts, Ts::new(i as u64 + 1, Gid(0)));
        assert!(o.deliverable);
    }
}

#[test]
fn engine_empty_batch_is_noop() {
    let eng = engine();
    assert!(eng.commit_batch(&[], &[]).unwrap().is_empty());
    assert_eq!(eng.calls.get(), 0);
}

#[test]
fn engine_deliverability_boundary() {
    let eng = engine();
    // gts exactly equal to pending min: NOT deliverable (strict <)
    let reqs = vec![BatchReq { m: MsgId::new(3, 1), lts: vec![Ts::new(5, Gid(2))] }];
    let out = eng.commit_batch(&reqs, &[Ts::new(5, Gid(2))]).unwrap();
    assert!(!out[0].deliverable);
    // one tick below: deliverable
    let out = eng.commit_batch(&reqs, &[Ts::new(5, Gid(3))]).unwrap();
    assert!(out[0].deliverable);
}

#[test]
fn quantile_engine_monotone() {
    let dir = wbam::runtime::engine::artifacts_dir();
    let q = QuantileEngine::load(&dir).expect("artifacts missing");
    let samples: Vec<u64> = (1..=1000).map(|i| i * 1000).collect();
    let qs = q.quantiles(&samples).unwrap();
    assert!(qs[0] <= qs[1] && qs[1] <= qs[2] && qs[2] <= qs[3], "{qs:?}");
    // p50 of 1..1000 ms-ish samples
    assert!((qs[0] - 500_000.0).abs() < 20_000.0, "{qs:?}");
}
