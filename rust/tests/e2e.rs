//! End-to-end integration over real threads: the in-process coordinator
//! runtime with the XLA commit backend, and the TCP transport cluster.
#![cfg_attr(not(feature = "xla"), allow(unused_imports))]

use std::time::{Duration, Instant};
use wbam::client::{Client, ClientCfg};
use wbam::coordinator::{spawn, spawn_sharded, Cluster, DeliverFn, NodeRuntime};
use wbam::net::{InProcMesh, TcpTransport, Transport};
use wbam::protocols::wbcast::{WbConfig, WbNode};
use wbam::protocols::Node;
use wbam::sync::atomic::AtomicBool;
use wbam::sync::{Arc, Mutex};
use wbam::types::{MsgId, Pid, ShardMap, Topology, Ts};

fn wait_for<F: Fn() -> bool>(pred: F, secs: u64, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !pred() {
        assert!(Instant::now() < deadline, "timeout waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Full three-layer composition: WbCast leaders commit through the AOT
/// XLA engine on a real-thread cluster; ordering checked per node.
/// Needs `--features xla` + `make artifacts`.
#[cfg(feature = "xla")]
#[test]
fn inproc_cluster_with_xla_backend() {
    use wbam::runtime::{spawn_engine, XlaBackend};
    let topo = Topology::new(3, 1);
    let engine = spawn_engine(wbam::runtime::engine::artifacts_dir()).expect("make artifacts");
    let wb = WbConfig {
        hb_interval: 30_000_000,
        batch_threshold: 4,
        batch_flush_after: 300_000,
        ..WbConfig::default()
    };
    let mut nodes: Vec<Box<dyn Node>> = Vec::new();
    for g in topo.gids() {
        for &p in topo.members(g) {
            nodes.push(Box::new(WbNode::with_backend(
                p,
                topo.clone(),
                wb,
                Box::new(XlaBackend::new(engine.clone())),
            )));
        }
    }
    for c in 0..6u32 {
        let pid = Pid(topo.first_client_pid().0 + c);
        let cfg = ClientCfg { dest_groups: 2, max_requests: Some(20), resend_after: 300_000_000, ..Default::default() };
        nodes.push(Box::new(Client::new(pid, topo.clone(), cfg, 0xE + c as u64)));
    }
    let deliveries = Arc::new(Mutex::new(Vec::<(Pid, MsgId, Ts)>::new()));
    let dv = Arc::clone(&deliveries);
    let cb: Arc<Mutex<DeliverFn>> = Arc::new(Mutex::new(Box::new(move |pid, m, gts, _| {
        dv.lock().unwrap().push((pid, m, gts));
    })));
    let cluster = Cluster::launch(nodes, Some(cb));
    // 6 clients x 20 requests x 2 groups x 3 replicas = 720 deliveries
    wait_for(|| deliveries.lock().unwrap().len() >= 720, 60, "720 deliveries");
    let nodes = cluster.shutdown();

    // per-node strictly increasing gts + agreement across nodes
    let dels = deliveries.lock().unwrap();
    let mut per_pid: std::collections::HashMap<Pid, Vec<Ts>> = Default::default();
    let mut gts_of: std::collections::HashMap<MsgId, Ts> = Default::default();
    for &(pid, m, gts) in dels.iter() {
        per_pid.entry(pid).or_default().push(gts);
        let e = gts_of.entry(m).or_insert(gts);
        assert_eq!(*e, gts, "gts disagreement for {m:?}");
    }
    for (pid, seq) in &per_pid {
        for w in seq.windows(2) {
            assert!(w[0] < w[1], "{pid:?} delivered out of gts order");
        }
    }
    // all clients finished
    for n in nodes {
        let any: &dyn Node = &*n;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            assert_eq!(c.completed.len(), 20);
        }
    }
    engine.shutdown();
}

/// The same protocol over real TCP sockets with the binary codec.
#[test]
fn tcp_cluster_end_to_end() {
    let topo = Topology::new(2, 1);
    let base = 46000 + (std::process::id() % 500) as u16 * 16;
    let mut addrs = std::collections::HashMap::new();
    for i in 0..8u32 {
        addrs.insert(Pid(i), format!("127.0.0.1:{}", base + i as u16).parse().unwrap());
    }
    let stop = Arc::new(AtomicBool::new(false));
    let delivered = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let mut handles = Vec::new();
    let mut nets = Vec::new();
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    for g in topo.gids() {
        for &p in topo.members(g) {
            let node: Box<dyn Node> = Box::new(WbNode::new(p, topo.clone(), wb));
            let t = TcpTransport::bind(p, addrs.clone()).expect("bind");
            nets.push(t.net_stats());
            let d = Arc::clone(&delivered);
            let cb: DeliverFn = Box::new(move |_pid, _m, _gts, _t| {
                d.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            });
            handles.push(spawn(node, t, Arc::clone(&stop), Some(cb)));
        }
    }
    std::thread::sleep(Duration::from_millis(100)); // listeners up
    // two clients, 10 requests each, to both groups
    let mut client_handles = Vec::new();
    for c in 0..2u32 {
        let pid = Pid(6 + c);
        let cfg = ClientCfg { dest_groups: 2, max_requests: Some(10), resend_after: 500_000_000, ..Default::default() };
        let node: Box<dyn Node> = Box::new(Client::new(pid, topo.clone(), cfg, 3 + c as u64));
        let t = TcpTransport::bind(pid, addrs.clone()).expect("bind client");
        nets.push(t.net_stats());
        let stop2 = Arc::clone(&stop);
        client_handles.push(std::thread::spawn(move || {
            let rt = NodeRuntime::new(node, t);
            rt.run(stop2)
        }));
    }
    // 2 clients x 10 requests x 2 groups x 3 replicas = 120 deliveries
    wait_for(|| delivered.load(std::sync::atomic::Ordering::Relaxed) >= 120, 60, "120 TCP deliveries");
    // happy path: no endpoint dropped a frame (checked before stop —
    // shutdown order can legitimately drop a final heartbeat)
    let dropped: u64 = nets.iter().map(|n| n.dropped_frames.load(std::sync::atomic::Ordering::Relaxed)).sum();
    assert_eq!(dropped, 0, "TCP transport dropped frames on the happy path");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut completed = 0;
    for h in client_handles {
        let node = h.join().unwrap();
        let any: &dyn Node = &*node;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    for h in handles {
        let _ = h.join().unwrap();
    }
    assert_eq!(completed, 20, "TCP cluster did not complete all requests");
}

/// Sharded runtime over real TCP sockets: 6 member endpoints each
/// hosting 2 shard nodes (2 groups x 2 shards), shard pids aliased to
/// their endpoint's address, clients partitioned across shards.
#[test]
fn tcp_sharded_cluster_end_to_end() {
    let map = ShardMap::new(2, 1, 2);
    let base = 52000 + (std::process::id() % 400) as u16 * 16;
    let mut addrs = std::collections::HashMap::new();
    for e in 0..6u32 {
        let addr = format!("127.0.0.1:{}", base + e as u16).parse().unwrap();
        for p in map.hosted_by(Pid(e)) {
            addrs.insert(p, addr);
        }
    }
    let n_clients = 2u32;
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        addrs.insert(pid, format!("127.0.0.1:{}", base + 8 + c as u16).parse().unwrap());
    }

    let stop = Arc::new(AtomicBool::new(false));
    let delivered = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    let mut handles = Vec::new();
    let mut nets = Vec::new();
    for e in 0..6u32 {
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        for p in map.hosted_by(Pid(e)) {
            let s = map.shard_of(p).expect("hosted pid is a member");
            nodes.push(Box::new(WbNode::new(p, map.topo(s), wb)));
        }
        let t = TcpTransport::bind(Pid(e), addrs.clone()).expect("bind endpoint");
        nets.push(t.net_stats());
        let d = Arc::clone(&delivered);
        let cb: DeliverFn = Box::new(move |_pid, _m, _gts, _t| {
            d.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        handles.push(spawn_sharded(nodes, t, Arc::clone(&stop), Some(cb)));
    }
    std::thread::sleep(Duration::from_millis(100)); // listeners up
    let mut client_handles = Vec::new();
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        let cfg = ClientCfg { dest_groups: 2, max_requests: Some(10), resend_after: 500_000_000, ..Default::default() };
        let node: Box<dyn Node> = Box::new(Client::new(pid, map.topo(map.client_shard(pid)), cfg, 3 + c as u64));
        let t = TcpTransport::bind(pid, addrs.clone()).expect("bind client");
        nets.push(t.net_stats());
        let stop2 = Arc::clone(&stop);
        client_handles.push(std::thread::spawn(move || NodeRuntime::new(node, t).run(stop2)));
    }
    // 2 clients x 10 requests x 2 groups x 3 replicas = 120 deliveries
    wait_for(|| delivered.load(std::sync::atomic::Ordering::Relaxed) >= 120, 60, "120 sharded TCP deliveries");
    // happy path: no endpoint dropped a frame
    let dropped: u64 = nets.iter().map(|n| n.dropped_frames.load(std::sync::atomic::Ordering::Relaxed)).sum();
    assert_eq!(dropped, 0, "sharded TCP transport dropped frames on the happy path");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut completed = 0;
    for h in client_handles {
        let node = h.join().unwrap();
        let any: &dyn Node = &*node;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    for h in handles {
        let _ = h.join().unwrap();
    }
    assert_eq!(completed, 20, "sharded TCP cluster did not complete all requests");
}

/// Tentpole acceptance (epoll parity): the exact 2×2-shard TCP cluster
/// scenario of `tcp_sharded_cluster_end_to_end`, but every endpoint
/// bound over the `EpollTransport` event loop — same delivered FIFO
/// workload completion, zero `dropped_frames` on any endpoint, while
/// the whole deployment runs **one event-loop thread per endpoint**
/// (asserted via thread names) instead of O(connections) reader
/// threads.
#[cfg(target_os = "linux")]
#[test]
fn epoll_sharded_cluster_parity() {
    use wbam::net::EpollTransport;

    /// Threads of this process named like an epoll event loop.
    fn epoll_threads() -> usize {
        let Ok(tasks) = std::fs::read_dir("/proc/self/task") else { return 0 };
        tasks
            .filter_map(|e| e.ok())
            .filter(|e| {
                std::fs::read_to_string(e.path().join("comm"))
                    .map(|c| c.trim().starts_with("wbam-epoll"))
                    .unwrap_or(false)
            })
            .count()
    }

    let map = ShardMap::new(2, 1, 2);
    let base = 58500 + (std::process::id() % 400) as u16 * 16;
    let mut addrs = std::collections::HashMap::new();
    for e in 0..6u32 {
        let addr = format!("127.0.0.1:{}", base + e as u16).parse().unwrap();
        for p in map.hosted_by(Pid(e)) {
            addrs.insert(p, addr);
        }
    }
    let n_clients = 2u32;
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        addrs.insert(pid, format!("127.0.0.1:{}", base + 8 + c as u16).parse().unwrap());
    }

    let stop = Arc::new(AtomicBool::new(false));
    let delivered = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    let mut handles = Vec::new();
    let mut nets = Vec::new();
    for e in 0..6u32 {
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        for p in map.hosted_by(Pid(e)) {
            let s = map.shard_of(p).expect("hosted pid is a member");
            nodes.push(Box::new(WbNode::new(p, map.topo(s), wb)));
        }
        let t = EpollTransport::bind(Pid(e), addrs.clone()).expect("bind endpoint");
        nets.push(t.net_stats());
        let d = Arc::clone(&delivered);
        let cb: DeliverFn = Box::new(move |_pid, _m, _gts, _t| {
            d.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        handles.push(spawn_sharded(nodes, t, Arc::clone(&stop), Some(cb)));
    }
    std::thread::sleep(Duration::from_millis(100)); // listeners up
    let mut client_handles = Vec::new();
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        let cfg = ClientCfg { dest_groups: 2, max_requests: Some(10), resend_after: 500_000_000, ..Default::default() };
        let node: Box<dyn Node> = Box::new(Client::new(pid, map.topo(map.client_shard(pid)), cfg, 3 + c as u64));
        let t = EpollTransport::bind(pid, addrs.clone()).expect("bind client");
        nets.push(t.net_stats());
        let stop2 = Arc::clone(&stop);
        client_handles.push(std::thread::spawn(move || NodeRuntime::new(node, t).run(stop2)));
    }
    // constant 1 event-loop thread per endpoint, however many
    // connections the 8 endpoints hold between them
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(epoll_threads(), 8, "expected exactly one event-loop thread per endpoint");
    // 2 clients x 10 requests x 2 groups x 3 replicas = 120 deliveries
    wait_for(|| delivered.load(std::sync::atomic::Ordering::Relaxed) >= 120, 60, "120 epoll deliveries");
    // parity with the threaded scenario: no endpoint dropped a frame
    let dropped: u64 = nets.iter().map(|n| n.dropped_frames.load(std::sync::atomic::Ordering::Relaxed)).sum();
    assert_eq!(dropped, 0, "epoll transport dropped frames on the happy path");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut completed = 0;
    for h in client_handles {
        let node = h.join().unwrap();
        let any: &dyn Node = &*node;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    for h in handles {
        let _ = h.join().unwrap();
    }
    assert_eq!(completed, 20, "epoll cluster did not complete all requests");
}

/// io_uring parity: the exact 2×2-shard cluster scenario of
/// `tcp_sharded_cluster_end_to_end` / `epoll_sharded_cluster_parity`,
/// but every endpoint bound over the `UringTransport` completion loop —
/// same workload completion, zero `dropped_frames` on any endpoint, one
/// ring thread per endpoint. Skips (with a printed reason) where the
/// kernel or sandbox can't run io_uring, so CI without io_uring stays
/// green.
#[cfg(target_os = "linux")]
#[test]
fn uring_sharded_cluster_parity() {
    use wbam::net::UringTransport;

    if let Err(reason) = wbam::net::uring_probe() {
        eprintln!("SKIP uring_sharded_cluster_parity: io_uring unavailable: {reason}");
        return;
    }

    /// Threads of this process named like an io_uring ring loop.
    fn uring_threads() -> usize {
        let Ok(tasks) = std::fs::read_dir("/proc/self/task") else { return 0 };
        tasks
            .filter_map(|e| e.ok())
            .filter(|e| {
                std::fs::read_to_string(e.path().join("comm"))
                    .map(|c| c.trim().starts_with("wbam-uring"))
                    .unwrap_or(false)
            })
            .count()
    }

    let map = ShardMap::new(2, 1, 2);
    let base = 36000 + (std::process::id() % 90) as u16 * 16;
    let mut addrs = std::collections::HashMap::new();
    for e in 0..6u32 {
        let addr = format!("127.0.0.1:{}", base + e as u16).parse().unwrap();
        for p in map.hosted_by(Pid(e)) {
            addrs.insert(p, addr);
        }
    }
    let n_clients = 2u32;
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        addrs.insert(pid, format!("127.0.0.1:{}", base + 8 + c as u16).parse().unwrap());
    }

    let stop = Arc::new(AtomicBool::new(false));
    let delivered = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };
    let mut handles = Vec::new();
    let mut nets = Vec::new();
    for e in 0..6u32 {
        let mut nodes: Vec<Box<dyn Node>> = Vec::new();
        for p in map.hosted_by(Pid(e)) {
            let s = map.shard_of(p).expect("hosted pid is a member");
            nodes.push(Box::new(WbNode::new(p, map.topo(s), wb)));
        }
        let t = UringTransport::bind(Pid(e), addrs.clone()).expect("bind endpoint");
        nets.push(t.net_stats());
        let d = Arc::clone(&delivered);
        let cb: DeliverFn = Box::new(move |_pid, _m, _gts, _t| {
            d.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        handles.push(spawn_sharded(nodes, t, Arc::clone(&stop), Some(cb)));
    }
    std::thread::sleep(Duration::from_millis(100)); // listeners up
    let mut client_handles = Vec::new();
    for c in 0..n_clients {
        let pid = Pid(map.first_client_pid().0 + c);
        let cfg = ClientCfg { dest_groups: 2, max_requests: Some(10), resend_after: 500_000_000, ..Default::default() };
        let node: Box<dyn Node> = Box::new(Client::new(pid, map.topo(map.client_shard(pid)), cfg, 3 + c as u64));
        let t = UringTransport::bind(pid, addrs.clone()).expect("bind client");
        nets.push(t.net_stats());
        let stop2 = Arc::clone(&stop);
        client_handles.push(std::thread::spawn(move || NodeRuntime::new(node, t).run(stop2)));
    }
    // constant 1 ring thread per endpoint, however many connections the
    // 8 endpoints hold between them
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(uring_threads(), 8, "expected exactly one ring thread per endpoint");
    // 2 clients x 10 requests x 2 groups x 3 replicas = 120 deliveries
    wait_for(|| delivered.load(std::sync::atomic::Ordering::Relaxed) >= 120, 60, "120 io_uring deliveries");
    // parity with the threaded scenario: no endpoint dropped a frame
    let dropped: u64 = nets.iter().map(|n| n.dropped_frames.load(std::sync::atomic::Ordering::Relaxed)).sum();
    assert_eq!(dropped, 0, "io_uring transport dropped frames on the happy path");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut completed = 0;
    for h in client_handles {
        let node = h.join().unwrap();
        let any: &dyn Node = &*node;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    for h in handles {
        let _ = h.join().unwrap();
    }
    assert_eq!(completed, 20, "io_uring cluster did not complete all requests");
}

/// Real-runtime leader failure under load: the mesh disconnect behaves
/// like a kill, the surviving members run the recovery protocol on real
/// threads (`Status::Recovering` → a new leader), delivery resumes, and
/// no surviving endpoint miscounts a frame (`CoordStats::dropped_frames`
/// stays zero — only the mesh's sends to the dead pid are dropped, and
/// those are counted separately in `NetStats`).
#[test]
fn inproc_leader_disconnect_recovers() {
    use wbam::types::Status;
    let topo = Topology::new(2, 1);
    let mesh = InProcMesh::new();
    let stop = Arc::new(AtomicBool::new(false));
    let wb = WbConfig {
        hb_interval: 20_000_000, // 20 ms: suspicion ~ hb*4*(1+rank)
        hb_suspect_mult: 4,
        retry_after: 400_000_000,
        recovery_timeout: 2_000_000_000,
        gc: false,
        ..WbConfig::default()
    };
    let mut handles = Vec::new();
    let mut coord_stats = Vec::new();
    let endpoints: Vec<_> = (0..6u32).map(|i| mesh.endpoint(Pid(i))).collect();
    for (i, ep) in endpoints.into_iter().enumerate() {
        let node: Box<dyn Node> = Box::new(WbNode::new(Pid(i as u32), topo.clone(), wb));
        let rt = NodeRuntime::new(node, ep);
        coord_stats.push((Pid(i as u32), rt.stats()));
        let stop2 = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || rt.run(stop2)));
    }
    let cpid = Pid(6);
    let ccfg = ClientCfg { dest_groups: 2, max_requests: Some(60), resend_after: 250_000_000, ..Default::default() };
    let cnode: Box<dyn Node> = Box::new(Client::new(cpid, topo.clone(), ccfg, 99));
    let cep = mesh.endpoint(cpid);
    let stop2 = Arc::clone(&stop);
    let ch = std::thread::spawn(move || NodeRuntime::new(cnode, cep).run(stop2));

    std::thread::sleep(Duration::from_millis(300));
    mesh.disconnect(Pid(0)); // crash the leader of group 0

    // give the cluster time to elect + catch up
    std::thread::sleep(Duration::from_secs(8));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let cnode = ch.join().unwrap();
    let any: &dyn Node = &*cnode;
    let c = (any as &dyn std::any::Any).downcast_ref::<Client>().unwrap();
    assert_eq!(c.completed.len(), 60, "client stalled after leader disconnect: {}", c.completed.len());
    let nodes: Vec<Box<dyn Node>> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // a surviving member of group 0 went through the recovery protocol
    // and holds the leadership now
    let mut new_leader = None;
    for n in &nodes {
        let any: &dyn Node = &**n;
        let wb = (any as &dyn std::any::Any).downcast_ref::<WbNode>().unwrap();
        if matches!(wb.pid(), Pid(1) | Pid(2)) && wb.status() == Status::Leader {
            assert!(wb.stats.recoveries_completed >= 1, "{:?} leads without recovering", wb.pid());
            new_leader = Some(wb.pid());
        }
    }
    assert!(new_leader.is_some(), "no surviving member of group 0 took over");
    // zero dropped_frames regression: no surviving endpoint ever saw a
    // frame it could not route
    for (p, s) in &coord_stats {
        if *p == Pid(0) {
            continue; // the victim's own counters are moot
        }
        assert_eq!(
            s.dropped_frames.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "{p:?} dropped routable frames during recovery"
        );
    }
}

/// Tentpole acceptance (real runtime): a member is killed under load and
/// restarted from its on-disk WAL (`Storage::open` → `WbNode::restore`);
/// it replays log + snapshot, rejoins via the recovery protocol, and the
/// cluster completes every request — with per-pid gts ordering intact
/// ACROSS the restart (the rebuilt node resumes above its journaled
/// watermark instead of re-delivering).
#[test]
fn durable_member_restart_rejoins_from_disk() {
    use wbam::storage::{Storage, SyncPolicy};
    let topo = Topology::new(2, 1);
    let dir = std::env::temp_dir().join(format!("wbam-e2e-restart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let wb = WbConfig {
        hb_interval: 20_000_000,
        hb_suspect_mult: 4,
        retry_after: 300_000_000,
        recovery_timeout: 700_000_000,
        gc: false,
        durability: true,
        ..WbConfig::default()
    };
    let mesh = InProcMesh::new();
    let stop = Arc::new(AtomicBool::new(false));
    let victim_stop = Arc::new(AtomicBool::new(false));
    let deliveries = Arc::new(Mutex::new(Vec::<(Pid, MsgId, Ts)>::new()));

    let mut handles = Vec::new();
    let mut victim_handle = None;
    for i in 0..6u32 {
        let p = Pid(i);
        let store = Storage::open(dir.join(format!("p{i}")), SyncPolicy::Always).expect("open storage");
        assert!(store.image().is_blank(), "fresh directory must start blank");
        let node: Box<dyn Node> = Box::new(WbNode::new(p, topo.clone(), wb));
        let ep = mesh.endpoint(p);
        let dv = Arc::clone(&deliveries);
        let stop2 = if i == 0 { Arc::clone(&victim_stop) } else { Arc::clone(&stop) };
        let h = std::thread::spawn(move || {
            let mut rt = NodeRuntime::new(node, ep);
            rt.attach_storage(store);
            rt.on_deliver(Box::new(move |pid, m, gts, _| dv.lock().unwrap().push((pid, m, gts))));
            rt.run(stop2)
        });
        if i == 0 {
            victim_handle = Some(h);
        } else {
            handles.push(h);
        }
    }
    let n_clients = 2u32;
    let requests = 40usize;
    let mut client_handles = Vec::new();
    for c in 0..n_clients {
        let cpid = Pid(6 + c);
        let ccfg = ClientCfg {
            dest_groups: 2,
            max_requests: Some(requests as u32),
            resend_after: 250_000_000,
            ..Default::default()
        };
        let cnode: Box<dyn Node> = Box::new(Client::new(cpid, topo.clone(), ccfg, 0xD0 + c as u64));
        let cep = mesh.endpoint(cpid);
        let stop2 = Arc::clone(&stop);
        client_handles.push(std::thread::spawn(move || NodeRuntime::new(cnode, cep).run(stop2)));
    }

    // let the durable cluster make visible progress...
    wait_for(|| deliveries.lock().unwrap().len() >= 60, 30, "pre-kill deliveries");
    // ...then kill the leader of group 0 (endpoint unreachable + thread
    // stopped; its WAL stays on disk)
    mesh.disconnect(Pid(0));
    victim_stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = victim_handle.take().unwrap().join().unwrap();
    let killed_at = deliveries.lock().unwrap().iter().filter(|d| d.0 == Pid(0)).count();
    assert!(killed_at > 0, "victim never delivered before the kill");

    // restart it from disk: the journal is non-blank, the node restores
    // and rejoins through the recovery protocol
    std::thread::sleep(Duration::from_millis(300));
    let store = Storage::open(dir.join("p0"), SyncPolicy::Always).expect("reopen storage");
    assert!(!store.image().is_blank(), "kill lost the journal");
    let node: Box<dyn Node> = Box::new(WbNode::restore(Pid(0), topo.clone(), wb, store.image()));
    let ep = mesh.endpoint(Pid(0));
    let dv = Arc::clone(&deliveries);
    let stop2 = Arc::clone(&stop);
    let restarted = std::thread::spawn(move || {
        let mut rt = NodeRuntime::new(node, ep);
        rt.attach_storage(store);
        rt.on_deliver(Box::new(move |pid, m, gts, _| dv.lock().unwrap().push((pid, m, gts))));
        rt.run(stop2)
    });

    // everything completes: 2 clients × 40 requests × 2 groups × 3
    // replicas — the restarted node catches up on what it missed
    let expected = n_clients as usize * requests * 2 * 3;
    wait_for(|| deliveries.lock().unwrap().len() >= expected, 60, "post-restart deliveries");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut completed = 0;
    for h in client_handles {
        let node = h.join().unwrap();
        let any: &dyn Node = &*node;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            completed += c.completed.len();
        }
    }
    assert_eq!(completed, n_clients as usize * requests, "clients stalled across the restart");
    for h in handles {
        let _ = h.join().unwrap();
    }
    let p0 = restarted.join().unwrap();
    let any: &dyn Node = &*p0;
    let p0 = (any as &dyn std::any::Any).downcast_ref::<WbNode>().unwrap();
    assert!(p0.stats.recoveries_started >= 1, "restarted node never re-joined");
    assert!(p0.stats.delivered > 0, "restarted node delivered nothing");

    // per-pid gts strictly increasing — for p0 ACROSS both incarnations
    // (Integrity + Ordering over the whole timeline)
    let dels = deliveries.lock().unwrap();
    let mut per_pid: std::collections::HashMap<Pid, Vec<Ts>> = Default::default();
    for &(pid, _m, gts) in dels.iter() {
        per_pid.entry(pid).or_default().push(gts);
    }
    assert!(per_pid[&Pid(0)].len() > killed_at, "no post-restart deliveries at p0");
    for (pid, seq) in &per_pid {
        for w in seq.windows(2) {
            assert!(w[0] < w[1], "{pid:?} delivered out of gts order across the restart");
        }
    }
    // every member converged on the complete delivery set (each message
    // goes to both groups, so every member delivers every message once)
    for p in 0..6u32 {
        assert_eq!(per_pid[&Pid(p)].len(), n_clients as usize * requests, "p{p} missed deliveries");
    }
    drop(dels);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// live observability: /metrics scraping under client load
// ---------------------------------------------------------------------

/// Minimal scrape client: one GET, read to EOF, return (status, body).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
    use std::io::{Read, Write};
    let mut conn = std::net::TcpStream::connect(addr).expect("connect metrics listener");
    write!(conn, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").expect("write request");
    let mut out = String::new();
    conn.read_to_string(&mut out).expect("read response");
    let code: u16 = out.split_whitespace().nth(1).expect("status line").parse().expect("status code");
    let body = out.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (code, body)
}

/// Value of the exposition line starting with `prefix` (exact metric
/// name + labels), e.g. `wbam_deliveries_total{path="fast"}`.
fn metric_value(body: &str, prefix: &str) -> u64 {
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix(prefix) {
            if let Ok(v) = rest.trim().parse() {
                return v;
            }
        }
    }
    panic!("metric {prefix:?} not found in scrape:\n{body}");
}

/// The shared scenario behind the tcp and epoll scrape tests: a 2-group
/// cluster where endpoint 0 carries the full observability stack
/// (registry + `CoreMetrics` + exposition listener), two *stamped*
/// clients drive load, and the scrape is checked against ground truth —
/// the white-box path counters must sum to the endpoint's delivered
/// count, and the exported latency quantiles must agree with the
/// clients' own completion measurements within histogram error.
fn scrape_under_load_scenario<T, F>(port_off: u16, bind: F)
where
    T: wbam::net::Transport + 'static,
    F: Fn(Pid, std::collections::HashMap<Pid, std::net::SocketAddr>) -> T,
{
    use wbam::obs::{register_coord_stats, register_net_stats, CoreMetrics, MetricsServer, Registry};
    let topo = Topology::new(2, 1);
    // 16-wide per-process stride, split 8/8 between the tcp and epoll
    // variants (they run concurrently in one test process)
    let base = 39000 + (std::process::id() % 300) as u16 * 16 + port_off;
    let mut addrs = std::collections::HashMap::new();
    for i in 0..8u32 {
        addrs.insert(Pid(i), format!("127.0.0.1:{}", base + i as u16).parse().unwrap());
    }
    let stop = Arc::new(AtomicBool::new(false));
    let wb = WbConfig { hb_interval: 50_000_000, ..WbConfig::default() };

    // endpoint 0 (initial leader of group 0) exports through one registry
    let reg = Arc::new(Registry::new());
    let cm = CoreMetrics::register(&reg);
    let mut handles = Vec::new();
    let mut coord0 = None;
    // cluster-wide delivery count: the shutdown condition (stopping on
    // endpoint 0's count alone could cut the clients' final group acks
    // mid-flight)
    let all_delivered = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    for g in topo.gids() {
        for &p in topo.members(g) {
            let node: Box<dyn Node> = Box::new(WbNode::new(p, topo.clone(), wb));
            let t = bind(p, addrs.clone());
            let net = t.net_stats();
            let stop2 = Arc::clone(&stop);
            let mut rt = NodeRuntime::new(node, t);
            let d = Arc::clone(&all_delivered);
            rt.on_deliver(Box::new(move |_p, _m, _g, _t| {
                d.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }));
            if p == Pid(0) {
                register_coord_stats(&reg, &rt.stats());
                register_net_stats(&reg, &net);
                coord0 = Some(rt.stats());
                rt.attach_metrics(Arc::clone(&cm));
            }
            handles.push(std::thread::spawn(move || rt.run(stop2)));
        }
    }
    // the registry serves from an ephemeral port; the window quantiles
    // are per-scrape, the _sum/_count pairs cumulative
    let srv =
        MetricsServer::serve("127.0.0.1:0", Arc::clone(&reg), Some(Arc::clone(&cm.flight))).expect("bind metrics listener");
    std::thread::sleep(Duration::from_millis(100)); // listeners up

    // pre-load scrape: the exposition schema must hold from startup.
    // Done before the clients start because every scrape drains the
    // histograms' interval window — the post-load scrape below must be
    // the first one to see the latency samples.
    let (code, early) = http_get(srv.addr, "/metrics");
    assert_eq!(code, 200);
    for ty in [
        "# TYPE wbam_deliveries_total counter",
        "# TYPE wbam_delivery_latency_ns summary",
        "# TYPE wbam_stage_wait_ns summary",
        "# TYPE wbam_distinct_clients gauge",
        "# TYPE wbam_coord_delivered_total counter",
        "# TYPE wbam_net_dropped_frames_total counter",
    ] {
        assert!(early.contains(ty), "missing {ty:?} in scrape:\n{early}");
    }

    let n_clients = 2u32;
    let requests = 15u32;
    let mut client_handles = Vec::new();
    for c in 0..n_clients {
        let pid = Pid(6 + c);
        // stamp: wall-clock submit stamps feed the server-side e2e
        // latency histogram; every message targets both groups, so
        // endpoint 0 delivers all of them
        let cfg = ClientCfg {
            dest_groups: 2,
            max_requests: Some(requests),
            resend_after: 500_000_000,
            stamp: true,
            ..Default::default()
        };
        let node: Box<dyn Node> = Box::new(Client::new(pid, topo.clone(), cfg, 11 + c as u64));
        let t = bind(pid, addrs.clone());
        let stop2 = Arc::clone(&stop);
        client_handles.push(std::thread::spawn(move || NodeRuntime::new(node, t).run(stop2)));
    }

    // ground truth: endpoint 0 delivers every one of the 30 multicasts,
    // and the whole cluster (2 clients x 15 requests x 2 groups x 3
    // replicas = 180 deliveries) finishes before the scrape
    let expected = (n_clients * requests) as u64;
    let coord0 = coord0.expect("endpoint 0 stats");
    wait_for(
        || {
            all_delivered.load(std::sync::atomic::Ordering::Relaxed) >= 6 * expected as usize
                && cm.delivered_total() >= expected
                && coord0.delivered.load(std::sync::atomic::Ordering::Relaxed) >= expected
        },
        60,
        "cluster-wide deliveries",
    );
    let (code, body) = http_get(srv.addr, "/metrics");
    assert_eq!(code, 200);

    // the white-box split must account for every delivery the runtime
    // counted — no path falls through unclassified on the wbcast path
    let fast = metric_value(&body, "wbam_deliveries_total{path=\"fast\"}");
    let concurrent = metric_value(&body, "wbam_deliveries_total{path=\"concurrent\"}");
    let recovery = metric_value(&body, "wbam_deliveries_total{path=\"recovery\"}");
    let unclassified = metric_value(&body, "wbam_deliveries_total{path=\"unclassified\"}");
    let delivered = metric_value(&body, "wbam_coord_delivered_total");
    assert_eq!(
        fast + concurrent + recovery + unclassified,
        delivered,
        "path counters must sum to the endpoint's deliveries (f={fast} c={concurrent} r={recovery} u={unclassified})"
    );
    assert_eq!(delivered, expected);
    assert_eq!(unclassified, 0, "wbcast deliveries must all be classified");
    assert_eq!(
        metric_value(&body, "wbam_delivery_latency_ns_count"),
        expected,
        "every stamped message must produce one e2e latency sample"
    );
    let hll = metric_value(&body, "wbam_distinct_clients");
    assert!((1..=4).contains(&hll), "HLL estimate {hll} for 2 clients");

    // flight recorder observed the run
    let (code, flight) = http_get(srv.addr, "/debug/flight");
    assert_eq!(code, 200);
    assert!(flight.contains("Deliver"), "flight ring missing deliveries:\n{flight}");

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut samples: Vec<u64> = Vec::new();
    for h in client_handles {
        let node = h.join().unwrap();
        let any: &dyn Node = &*node;
        if let Some(c) = (any as &dyn std::any::Any).downcast_ref::<Client>() {
            assert_eq!(c.completed.len(), requests as usize);
            samples.extend(c.completed.iter().map(|s| s.done_at - s.sent_at));
        }
    }
    for h in handles {
        let _ = h.join().unwrap();
    }

    // latency agreement: a delivery at the member precedes the client's
    // completion (one extra notification hop), so the exported
    // distribution must sit at-or-below the client's own — within
    // histogram bucket error (~2x slack) and never at zero
    let p50 = metric_value(&body, "wbam_delivery_latency_ns{quantile=\"0.5\"}");
    let p99 = metric_value(&body, "wbam_delivery_latency_ns{quantile=\"0.99\"}");
    let cmax = *samples.iter().max().expect("client samples");
    assert!(p50 > 0 && p50 <= p99, "degenerate exported quantiles p50={p50} p99={p99}");
    assert!(p99 <= cmax.saturating_mul(2), "exported p99 {p99} vs client max {cmax}");
    let mean_exported =
        metric_value(&body, "wbam_delivery_latency_ns_sum") / metric_value(&body, "wbam_delivery_latency_ns_count");
    let mean_client = samples.iter().sum::<u64>() / samples.len() as u64;
    assert!(
        mean_exported <= mean_client.saturating_mul(2),
        "exported mean {mean_exported} vs client completion mean {mean_client}"
    );
    drop(srv);
}

/// Tentpole acceptance: scraping `/metrics` over the **tcp** transport
/// while stamped clients drive load.
#[test]
fn metrics_scrape_under_tcp_load() {
    scrape_under_load_scenario(0, |p, addrs| TcpTransport::bind(p, addrs).expect("bind tcp"));
}

/// The same scrape scenario over the **epoll** event-loop transport.
#[cfg(target_os = "linux")]
#[test]
fn metrics_scrape_under_epoll_load() {
    scrape_under_load_scenario(8, |p, addrs| wbam::net::EpollTransport::bind(p, addrs).expect("bind epoll"));
}
